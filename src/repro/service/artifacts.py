"""Content-addressed artifact store — service layer L2 (DESIGN.md §7.2, §14).

Persists trained ``PerfModel``s, selections, and plan metadata so repeat
optimisation runs warm-start in milliseconds — the paper's Table 4 claim
("optimising a network costs seconds, not hours") made operational across
process restarts, and (§14) across *hosts*: the store now sits on a
pluggable :class:`~repro.service.store_backends.StoreBackend`, so a fleet
of serving machines shares one calibration instead of each re-profiling.

Addressing: an artifact's identity is a dict of key fields — canonically
(platform fingerprint, backend name, columns, dataset fingerprint, model
kind) plus role/mode/seed — serialised to canonical JSON and hashed
(sha256, 16 hex chars). Same inputs => same address => warm hit; any drift
in the profiled data or model configuration changes the address and forces
a retrain. No cache-invalidation logic exists because none is needed.
The backend name rides in every model and selection address (DESIGN.md §9)
so two backends optimising the same network can never collide on an
artifact, even if their platform fingerprints were ever to coincide — each
backend's warm start is byte-identical to its own cold result.

Durability — the staged-upload-then-manifest-commit protocol (§14.2):
an entry is the key group ``{category}/{digest}/``. Publish uploads the
payload under a fresh staged name (``stage.<pid>-<seq>.<payload>``),
then commits ``manifest.json`` — payload checksum, key fields, and the
staged payload name — with one atomic key put, LAST. An entry without a
manifest, or whose manifest's payload is missing or checksum-mismatched,
is invisible. A writer killed at any point leaves either the old entry
(manifest still names the old payload) or the new one — never a readable
partial — and ``sweep()`` collects the orphaned staged uploads. Entries
written by the pre-backend layout (payload under its plain name) remain
readable.

Fleet calibration pooling (§14.3): ``publish_drift`` pushes a host's
served-traffic ``PerfDataset`` (drift attribution, DESIGN.md §8.5) into
the shared ``drift_pool`` category keyed by platform fingerprint;
``pooled_drift`` returns every *other* host's newest evidence for the
same fingerprint, so one host's drift excursion becomes every host's
free recalibration.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.perfmodel import PerfModel
from repro.service.store_backends import (BackendError, LocalDirBackend,
                                          StoreBackend)

_MODEL_PAYLOAD = "model.npz"
_JSON_PAYLOAD = "data.json"
_DATASET_PAYLOAD = "dataset.npz"
_MANIFEST = "manifest.json"


def digest(fields: Dict[str, Any]) -> str:
    """Canonical-JSON sha256 address of a key-field dict."""
    blob = json.dumps(fields, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class ArtifactStore:
    def __init__(self, root: Optional[str] = None, keep: Optional[int] = None,
                 *, backend: Optional[StoreBackend] = None,
                 clock: Callable[[], float] = time.time):
        """``keep`` enables opportunistic per-category GC: after every put,
        only the newest ``keep`` artifacts of that category are retained
        (à la ``ckpt/manager.py``) — so e.g. the serving drift loop's
        recalibration generations cannot grow the store without bound.
        ``None`` (default) keeps everything. Retention is by age alone:
        ``keep`` must cover the category's live working set (e.g. at least
        2 for a HostPlatform's prim+dlt datasets, one model pair per
        platform in ``models``) or warm-starts silently thrash.

        ``backend`` selects where bytes live; default is the original
        local directory at ``root``. ``clock`` stamps manifests and drives
        age-gated GC — injectable for deterministic fleet tests."""
        if keep is not None and keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        if backend is None:
            if root is None:
                raise ValueError("ArtifactStore needs a root or a backend")
            backend = LocalDirBackend(root)
        self.root = root
        self.keep = keep
        self.backend = backend
        self.clock = clock
        self._seq = itertools.count()

    # -- keys ----------------------------------------------------------------
    def _prefix(self, category: str, key: str) -> str:
        return f"{category}/{key}"

    def path(self, category: str, fields: Dict[str, Any]) -> str:
        """The entry's location: a real directory for the local backend,
        the key prefix otherwise."""
        prefix = self._prefix(category, digest(fields))
        if isinstance(self.backend, LocalDirBackend):
            return os.path.join(self.backend.root, *prefix.split("/"))
        return prefix

    # -- manifest / validity -------------------------------------------------
    def _manifest(self, category: str, key: str) -> Optional[Dict[str, Any]]:
        try:
            data = self.backend.get(f"{self._prefix(category, key)}/{_MANIFEST}")
            if data is None:
                return None
            return json.loads(data.decode())
        except (OSError, ValueError, UnicodeDecodeError):
            return None

    def _checksum_ok(self, category: str, key: str,
                     man: Dict[str, Any]) -> bool:
        payload = man.get("payload")
        if not isinstance(payload, str):
            return False
        try:
            stream = self.backend.get_stream(
                f"{self._prefix(category, key)}/{payload}")
            if stream is None:
                return False
            h = hashlib.sha256()
            for chunk in stream:
                h.update(chunk)
            return man.get("checksum") == h.hexdigest()
        except (OSError, ValueError):
            return False

    def _valid_manifest(self, category: str,
                        key: str) -> Optional[Dict[str, Any]]:
        man = self._manifest(category, key)
        if man is None or not self._checksum_ok(category, key, man):
            return None
        return man

    # -- generic put/get -----------------------------------------------------
    def _put(self, category: str, fields: Dict[str, Any], payload_name: str,
             write_payload: Callable[[str], None]) -> str:
        key = digest(fields)
        prefix = self._prefix(category, key)
        with tempfile.TemporaryDirectory(prefix="artifact.") as td:
            local = os.path.join(td, payload_name)
            write_payload(local)
            checksum = _file_sha256(local)
            with open(local, "rb") as f:
                data = f.read()
        staged = f"stage.{os.getpid()}-{next(self._seq)}.{payload_name}"
        # 1) staged upload — invisible: no manifest names it yet
        self.backend.put(f"{prefix}/{staged}", data)
        manifest = {
            "key": key,
            "fields": fields,
            "payload": staged,
            "checksum": checksum,
            "created": self.clock(),
        }
        # 2) commit — one atomic key put marks the entry complete and
        #    atomically supersedes any previous payload of this address
        self.backend.put(
            f"{prefix}/{_MANIFEST}",
            json.dumps(manifest, indent=1, default=str).encode())
        self._collect_superseded(category, key)
        if self.keep is not None:
            self.sweep(self.keep, category=category)
        return self.path(category, fields)

    def _collect_superseded(self, category: str, key: str) -> None:
        """Best-effort: drop payloads the committed manifest no longer
        names (an overwritten entry's old bytes). Re-reads the manifest so
        a concurrent same-address publisher's winning payload survives."""
        prefix = self._prefix(category, key)
        try:
            man = self._manifest(category, key)
            live = man.get("payload") if man else None
            for k in self.backend.list(prefix + "/"):
                rest = k[len(prefix) + 1:]
                if rest in (_MANIFEST, live) or not rest:
                    continue
                self.backend.delete(k)
        except OSError:
            pass

    def _load(self, category: str, fields: Dict[str, Any],
              loader: Callable[[str], Any]) -> Optional[Any]:
        """Validate, then hand the payload to a path-based loader — via the
        backend's local file when it has one, else through a temp spool."""
        key = digest(fields)
        man = self._valid_manifest(category, key)
        if man is None:
            return None
        payload_key = f"{self._prefix(category, key)}/{man['payload']}"
        local = self.backend.local_path(payload_key)
        if local is not None:
            return loader(local)
        stream = self.backend.get_stream(payload_key)
        if stream is None:
            return None
        with tempfile.TemporaryDirectory(prefix="artifact.") as td:
            spool = os.path.join(td, os.path.basename(man["payload"]))
            with open(spool, "wb") as f:
                for chunk in stream:
                    f.write(chunk)
            return loader(spool)

    # -- models --------------------------------------------------------------
    def put_model(self, fields: Dict[str, Any], model: PerfModel) -> str:
        return self._put("models", fields, _MODEL_PAYLOAD, model.save)

    def get_model(self, fields: Dict[str, Any]) -> Optional[PerfModel]:
        return self._load("models", fields, PerfModel.load)

    def get_or_train(self, fields: Dict[str, Any],
                     train_fn: Callable[[], PerfModel]) -> Tuple[PerfModel, bool]:
        """(model, warm): warm-load on address hit, else train and persist.
        A store that fails to persist (read-only root, unreachable backend)
        never discards the freshly trained model — caching failures cost
        the cache, not the training."""
        try:
            m = self.get_model(fields)
        except OSError:
            m = None
        if m is not None:
            return m, True
        m = train_fn()
        try:
            self.put_model(fields, m)
        except OSError:
            pass
        return m, False

    # -- JSON artifacts (selections, plan metadata) --------------------------
    def put_json(self, category: str, fields: Dict[str, Any], obj: Any) -> str:
        def write(path: str) -> None:
            with open(path, "w") as f:
                json.dump(obj, f, indent=1, default=str)
        return self._put(category, fields, _JSON_PAYLOAD, write)

    def get_json(self, category: str, fields: Dict[str, Any]) -> Optional[Any]:
        def load(path: str) -> Any:
            with open(path) as f:
                return json.load(f)
        return self._load(category, fields, load)

    # -- datasets (profiled-measurement warm-start, pooled drift evidence) ---
    def put_dataset(self, fields: Dict[str, Any], dataset,
                    category: str = "datasets") -> str:
        return self._put(category, fields, _DATASET_PAYLOAD, dataset.save)

    def get_dataset(self, fields: Dict[str, Any],
                    category: str = "datasets"):
        from repro.profiler.dataset import PerfDataset
        return self._load(category, fields, PerfDataset.load)

    def delete(self, category: str, fields: Dict[str, Any]) -> bool:
        """Remove one artifact (e.g. a host dataset known to be stale after
        platform drift). True if something was deleted."""
        prefix = self._prefix(category, digest(fields))
        try:
            return self.backend.delete_prefix(prefix + "/") > 0
        except OSError:
            return False

    # -- fleet calibration pooling (DESIGN.md §14.3) -------------------------
    def publish_drift(self, platform_fp: str, dataset, *, host: str,
                      net: Optional[str] = None) -> str:
        """Publish one host's served-traffic evidence for its platform
        fingerprint. Monotonic per-host ``seq`` makes re-publishes ordered;
        one retry absorbs a transient backend fault (the commit protocol
        makes a half-published attempt invisible, so retrying is safe)."""
        seq = 0
        for man in self.drift_entries(platform_fp):
            f = man.get("fields", {})
            if f.get("host") == host:
                seq = max(seq, int(f.get("seq", 0)) + 1)
        fields = {"artifact": "drift_pool", "platform": platform_fp,
                  "host": host, "net": net, "seq": seq,
                  "data": dataset.fingerprint()}
        try:
            return self.put_dataset(fields, dataset, category="drift_pool")
        except BackendError:
            return self.put_dataset(fields, dataset, category="drift_pool")

    def drift_entries(self, platform_fp: str,
                      exclude_host: Optional[str] = None) -> List[Dict[str, Any]]:
        """Valid drift-pool manifests for ``platform_fp``, ordered by
        (host, seq) for determinism."""
        out = []
        for man in self.entries("drift_pool"):
            f = man.get("fields", {})
            if f.get("platform") != platform_fp:
                continue
            if exclude_host is not None and f.get("host") == exclude_host:
                continue
            out.append(man)
        out.sort(key=lambda m: (str(m["fields"].get("host")),
                                int(m["fields"].get("seq", 0)),
                                m.get("key", "")))
        return out

    def pooled_drift(self, platform_fp: str, *,
                     exclude_host: Optional[str] = None) -> List["Any"]:
        """The fleet's pooled evidence: each other host's newest dataset
        for this fingerprint. Unreadable entries (a host mid-publish, a
        faulty backend read) are skipped, not fatal — pooling is additive."""
        newest: Dict[str, Dict[str, Any]] = {}
        for man in self.drift_entries(platform_fp, exclude_host=exclude_host):
            newest[str(man["fields"].get("host"))] = man
        out = []
        for host in sorted(newest):
            man = newest[host]
            try:
                ds = self.get_dataset(man["fields"], category="drift_pool")
            except (OSError, ValueError):
                ds = None
            if ds is not None and ds.n:
                out.append(ds)
        return out

    # -- retention / GC ------------------------------------------------------
    def sweep(self, keep: Optional[int] = None,
              category: Optional[str] = None,
              grace_s: float = 3600.0) -> int:
        """Garbage-collect the store. Always removed: corrupt or partially
        written entries (missing/unparsable manifest, payload missing or
        checksum-mismatched — invisible to reads but otherwise immortal),
        stale ``tmp.`` dirs from pre-backend crashed writers, and orphaned
        staged uploads older than ``grace_s`` that no manifest names. With
        ``keep`` additionally retain only the newest ``keep`` valid
        artifacts per category (manifest ``created`` time; ties broken by
        key for determinism). ``keep=None`` is the pure GC pass: collect
        garbage, trim nothing. Returns the number of *entries* removed
        (orphaned staged keys and tmp dirs are collected but not counted,
        matching the original semantics)."""
        removed = 0
        now = self.clock()
        groups: Dict[Tuple[str, str], List[str]] = {}
        try:
            keys = self.backend.list(f"{category}/" if category else "")
        except OSError:
            return 0
        for k in keys:
            parts = k.split("/")
            # a bare "<category>/" pseudo-key (empty local dir) is not an
            # entry — deleting its "" group would rmtree the whole category
            if len(parts) < 2 or not parts[1]:
                continue
            groups.setdefault((parts[0], parts[1]), []).append(
                "/".join(parts[2:]))
        by_cat: Dict[str, List[Tuple[float, str]]] = {}
        for (cat, entry), rests in sorted(groups.items()):
            prefix = f"{cat}/{entry}"
            # every per-entry read tolerates a concurrent sweeper (e.g. a
            # drift-recalibration thread) deleting it under us
            try:
                if entry.startswith("tmp."):
                    mt = self.backend.mtime(prefix + "/")
                    if mt is None:
                        mt = max((self.backend.mtime(f"{prefix}/{r}") or now)
                                 for r in rests)
                    if now - mt > grace_s:
                        self.backend.delete_prefix(prefix + "/")
                    continue
                man = self._manifest(cat, entry)
                if man is None or not self._checksum_ok(cat, entry, man):
                    self.backend.delete_prefix(prefix + "/")
                    removed += 1
                    continue
                live = man.get("payload")
                for rest in rests:
                    if (rest.startswith("stage.") and rest != live):
                        mt = self.backend.mtime(f"{prefix}/{rest}")
                        if mt is None or now - mt > grace_s:
                            self.backend.delete(f"{prefix}/{rest}")
                created = float(man.get("created", 0.0))
            except (OSError, ValueError):
                continue
            by_cat.setdefault(cat, []).append((created, entry))
        if keep is not None and keep > 0:
            for cat, aged in by_cat.items():
                aged.sort()
                for _, entry in aged[:-keep]:
                    try:
                        self.backend.delete_prefix(f"{cat}/{entry}/")
                        removed += 1
                    except OSError:
                        continue
        return removed

    # -- introspection -------------------------------------------------------
    def entries(self, category: Optional[str] = None) -> List[Dict[str, Any]]:
        """Manifests of all valid artifacts (debugging / GC tooling / fleet
        pooling)."""
        out = []
        try:
            keys = self.backend.list(f"{category}/" if category else "")
        except OSError:
            return []
        seen = set()
        for k in sorted(keys):
            parts = k.split("/")
            if len(parts) < 2 or not parts[1]:
                continue
            cat, entry = parts[0], parts[1]
            if (cat, entry) in seen or entry.startswith("tmp."):
                continue
            seen.add((cat, entry))
            man = self._valid_manifest(cat, entry)
            if man is None:
                continue
            man["category"] = cat
            out.append(man)
        return out


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
