"""Content-addressed artifact store — service layer L2 (DESIGN.md §7.2).

Persists trained ``PerfModel``s, selections, and plan metadata so repeat
optimisation runs warm-start in milliseconds — the paper's Table 4 claim
("optimising a network costs seconds, not hours") made operational across
process restarts.

Addressing: an artifact's identity is a dict of key fields — canonically
(platform fingerprint, backend name, columns, dataset fingerprint, model
kind) plus role/mode/seed — serialised to canonical JSON and hashed
(sha256, 16 hex chars). Same inputs => same address => warm hit; any drift
in the profiled data or model configuration changes the address and forces
a retrain. No cache-invalidation logic exists because none is needed.
The backend name rides in every model and selection address (DESIGN.md §9)
so two backends optimising the same network can never collide on an
artifact, even if their platform fingerprints were ever to coincide — each
backend's warm start is byte-identical to its own cold result.

Durability (in the style of ``ckpt/manager.py``): each artifact is a
directory written under a temp name and ``os.replace``d into place, with a
``manifest.json`` (payload checksum + the human-readable key fields) written
last; an entry without a valid manifest is invisible. A killed writer can
never leave a readable-but-corrupt artifact.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.perfmodel import PerfModel

_MODEL_PAYLOAD = "model.npz"
_JSON_PAYLOAD = "data.json"
_DATASET_PAYLOAD = "dataset.npz"


def digest(fields: Dict[str, Any]) -> str:
    """Canonical-JSON sha256 address of a key-field dict."""
    blob = json.dumps(fields, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class ArtifactStore:
    def __init__(self, root: str, keep: Optional[int] = None):
        """``keep`` enables opportunistic per-category GC: after every put,
        only the newest ``keep`` artifacts of that category are retained
        (à la ``ckpt/manager.py``) — so e.g. the serving drift loop's
        recalibration generations cannot grow the store without bound.
        ``None`` (default) keeps everything. Retention is by age alone:
        ``keep`` must cover the category's live working set (e.g. at least
        2 for a HostPlatform's prim+dlt datasets, one model pair per
        platform in ``models``) or warm-starts silently thrash."""
        if keep is not None and keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # -- paths -------------------------------------------------------------
    def _dir(self, category: str, key: str) -> str:
        return os.path.join(self.root, category, key)

    def path(self, category: str, fields: Dict[str, Any]) -> str:
        return self._dir(category, digest(fields))

    # -- generic put/get ---------------------------------------------------
    def _put(self, category: str, fields: Dict[str, Any], payload_name: str,
             write_payload: Callable[[str], None]) -> str:
        key = digest(fields)
        final = self._dir(category, key)
        parent = os.path.dirname(final)
        os.makedirs(parent, exist_ok=True)
        tmp = os.path.join(parent, f"tmp.{key}.{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        payload = os.path.join(tmp, payload_name)
        write_payload(payload)
        manifest = {
            "key": key,
            "fields": fields,
            "payload": payload_name,
            "checksum": _file_sha256(payload),
            "created": time.time(),
        }
        # manifest written LAST: its presence marks the artifact complete
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, default=str)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        if self.keep is not None:
            self.sweep(self.keep, category=category)
        return final

    def _valid(self, d: str) -> bool:
        man = os.path.join(d, "manifest.json")
        if not os.path.exists(man):
            return False
        try:
            with open(man) as f:
                m = json.load(f)
            payload = os.path.join(d, m["payload"])
            return (os.path.exists(payload)
                    and m.get("checksum") == _file_sha256(payload))
        except (json.JSONDecodeError, OSError, KeyError):
            return False

    # -- models ------------------------------------------------------------
    def put_model(self, fields: Dict[str, Any], model: PerfModel) -> str:
        return self._put("models", fields, _MODEL_PAYLOAD, model.save)

    def get_model(self, fields: Dict[str, Any]) -> Optional[PerfModel]:
        d = self.path("models", fields)
        if not self._valid(d):
            return None
        return PerfModel.load(os.path.join(d, _MODEL_PAYLOAD))

    def get_or_train(self, fields: Dict[str, Any],
                     train_fn: Callable[[], PerfModel]) -> Tuple[PerfModel, bool]:
        """(model, warm): warm-load on address hit, else train and persist.
        A store that fails to persist (read-only root) never discards the
        freshly trained model — caching failures cost the cache, not the
        training."""
        try:
            m = self.get_model(fields)
        except OSError:
            m = None
        if m is not None:
            return m, True
        m = train_fn()
        try:
            self.put_model(fields, m)
        except OSError:
            pass
        return m, False

    # -- JSON artifacts (selections, plan metadata) -------------------------
    def put_json(self, category: str, fields: Dict[str, Any], obj: Any) -> str:
        def write(path: str) -> None:
            with open(path, "w") as f:
                json.dump(obj, f, indent=1, default=str)
        return self._put(category, fields, _JSON_PAYLOAD, write)

    def get_json(self, category: str, fields: Dict[str, Any]) -> Optional[Any]:
        d = self.path(category, fields)
        if not self._valid(d):
            return None
        with open(os.path.join(d, _JSON_PAYLOAD)) as f:
            return json.load(f)

    # -- datasets (HostPlatform profiled-measurement warm-start) -------------
    def put_dataset(self, fields: Dict[str, Any], dataset) -> str:
        return self._put("datasets", fields, _DATASET_PAYLOAD, dataset.save)

    def get_dataset(self, fields: Dict[str, Any]):
        from repro.profiler.dataset import PerfDataset
        d = self.path("datasets", fields)
        if not self._valid(d):
            return None
        return PerfDataset.load(os.path.join(d, _DATASET_PAYLOAD))

    def delete(self, category: str, fields: Dict[str, Any]) -> bool:
        """Remove one artifact (e.g. a host dataset known to be stale after
        platform drift). True if something was deleted."""
        d = self.path(category, fields)
        if not os.path.isdir(d):
            return False
        shutil.rmtree(d, ignore_errors=True)
        return True

    # -- retention / GC ------------------------------------------------------
    def sweep(self, keep: Optional[int] = None,
              category: Optional[str] = None) -> int:
        """Garbage-collect the store. Always removed: corrupt or partially
        written entries (missing/unparsable manifest, payload checksum
        mismatch — invisible to reads but otherwise immortal) and stale
        ``tmp.`` dirs from crashed writers. With ``keep`` additionally
        retain only the newest ``keep`` valid artifacts per category
        (manifest ``created`` time; ties broken by key for determinism).
        ``keep=None`` is the pure GC pass: collect garbage, trim nothing.
        Returns the number of artifacts removed."""
        removed = 0
        cats = [category] if category else sorted(
            d for d in os.listdir(self.root)
            if os.path.isdir(os.path.join(self.root, d)))
        for cat in cats:
            cat_dir = os.path.join(self.root, cat)
            if not os.path.isdir(cat_dir):
                continue
            aged = []
            for key in os.listdir(cat_dir):
                d = os.path.join(cat_dir, key)
                # every per-entry stat/read tolerates a concurrent sweeper
                # (e.g. a drift-recalibration thread) deleting it under us
                try:
                    if key.startswith("tmp."):
                        if time.time() - os.path.getmtime(d) > 3600:
                            shutil.rmtree(d, ignore_errors=True)
                        continue
                    if not self._valid(d):   # corrupt/partial: collect
                        shutil.rmtree(d, ignore_errors=True)
                        removed += 1
                        continue
                    with open(os.path.join(d, "manifest.json")) as f:
                        created = json.load(f).get("created", 0.0)
                except (OSError, json.JSONDecodeError):
                    continue
                aged.append((created, key))
            aged.sort()
            stale = aged[:-keep] if keep is not None and keep > 0 else []
            for _, key in stale:
                shutil.rmtree(os.path.join(cat_dir, key), ignore_errors=True)
                removed += 1
        return removed

    # -- introspection -------------------------------------------------------
    def entries(self, category: Optional[str] = None) -> List[Dict[str, Any]]:
        """Manifests of all valid artifacts (debugging / GC tooling)."""
        out = []
        cats = [category] if category else sorted(
            d for d in os.listdir(self.root)
            if os.path.isdir(os.path.join(self.root, d)))
        for cat in cats:
            cat_dir = os.path.join(self.root, cat)
            if not os.path.isdir(cat_dir):
                continue
            for key in sorted(os.listdir(cat_dir)):
                d = os.path.join(cat_dir, key)
                if key.startswith("tmp.") or not self._valid(d):
                    continue
                with open(os.path.join(d, "manifest.json")) as f:
                    m = json.load(f)
                m["category"] = cat
                out.append(m)
        return out


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
