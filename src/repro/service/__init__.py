"""Service layer (DESIGN.md §7): platform abstraction, artifact store, and
serving front end — the profile → model → select → serve pipeline as a
subsystem instead of per-script glue.

    from repro.service import ArtifactStore, OptimisedServer, get_platform, optimise

    store = ArtifactStore("artifacts")
    arm = get_platform("arm")
    base = get_platform("intel").pretrain("nn2", store=store)
    opt = optimise("edge_cnn", arm, store=store, base=base, executable=True)
    server = OptimisedServer()
    server.register(opt)
"""
from repro.service.artifacts import ArtifactStore, digest
from repro.service.pipeline import OptimisedNetwork, optimise
from repro.service.platforms import (HostPlatform, Platform, PlatformModels,
                                     SimulatedPlatform, get_platform)
from repro.service.server import OptimisedServer, Ticket

__all__ = [
    "ArtifactStore", "digest",
    "HostPlatform", "OptimisedNetwork", "OptimisedServer", "Platform",
    "PlatformModels", "SimulatedPlatform", "Ticket",
    "get_platform", "optimise",
]
