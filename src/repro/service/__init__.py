"""Service layer (DESIGN.md §7–§8): platform abstraction, artifact store,
and the concurrent serving core — the profile → model → select → serve →
observe → recalibrate pipeline as a subsystem instead of per-script glue.

    from repro.service import ArtifactStore, OptimisedServer, get_platform, optimise

    store = ArtifactStore("artifacts", keep=32)
    arm = get_platform("arm")
    base = get_platform("intel").pretrain("nn2", store=store)
    opt = optimise("edge_cnn", arm, store=store, base=base, executable=True)
    server = OptimisedServer(workers=2, max_wait_ms=5.0)
    server.register(opt)
"""
from repro.service.artifacts import ArtifactStore, digest
from repro.service.pipeline import (OptimisedNetwork, optimise, reoptimise,
                                    safe_assignment)
from repro.service.store_backends import (BackendError, LocalDirBackend,
                                          ObjectStoreBackend, ScriptedFaults,
                                          StoreBackend, get_backend)
from repro.service.platforms import (HostPlatform, PallasPlatform, Platform,
                                     PlatformModels, SimulatedPlatform,
                                     get_platform, host_machine_id)
from repro.service.serving import (BatchGroup, CircuitBreaker,
                                   CorruptOutput, DriftMonitor, DriftStats,
                                   Fault, FaultError, FaultInjector,
                                   LayerProfile, NetQueue, OptimisedServer,
                                   ProcessFrontend, ServedObservation,
                                   SlabHandle, SlabPool, Ticket, WorkerPool,
                                   layer_profile, make_recalibrator)

__all__ = [
    "ArtifactStore", "BackendError", "digest",
    "BatchGroup", "CircuitBreaker", "CorruptOutput",
    "DriftMonitor", "DriftStats", "Fault", "FaultError", "FaultInjector",
    "HostPlatform", "LayerProfile", "LocalDirBackend", "NetQueue",
    "ObjectStoreBackend",
    "OptimisedNetwork", "OptimisedServer", "PallasPlatform", "Platform",
    "PlatformModels", "ProcessFrontend", "ScriptedFaults",
    "ServedObservation", "SimulatedPlatform", "SlabHandle", "SlabPool",
    "StoreBackend", "Ticket", "WorkerPool",
    "get_backend", "get_platform", "host_machine_id", "layer_profile",
    "make_recalibrator", "optimise", "reoptimise", "safe_assignment",
]
