"""Optimizers and LR schedules, pure JAX (no optax dependency).

Implements the optimizers the paper uses (Adam, Table 3) plus the ones the
large-scale training substrate needs (AdamW with decoupled weight decay,
Adafactor with factored second moments — required to fit llama3-405b optimizer
state in v5e HBM, see DESIGN.md §4), gradient clipping and schedules.

All optimizers follow the same functional interface:

    opt = adam(lr=1e-3)
    state = opt.init(params)
    params, state = opt.update(params, grads, state)

States are pytrees of arrays, so they are jit/pjit/checkpoint friendly. The
``step`` counter lives in the state. ``lr`` may be a float or a callable
``step -> lr`` (schedule).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]
LR = Union[float, Schedule]


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Params, Any], tuple[Params, Any]]


def _resolve_lr(lr: LR, step: jnp.ndarray) -> jnp.ndarray:
    if callable(lr):
        return jnp.asarray(lr(step), dtype=jnp.float32)
    return jnp.asarray(lr, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def warmup_cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                           floor: float = 0.0) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        frac = jnp.clip((step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def step_decay_schedule(base: float, decay: float, every: int) -> Schedule:
    """Multiply lr by ``decay`` every ``every`` steps (paper's fine-tune: x0.1)."""
    def sched(step):
        k = jnp.floor(jnp.asarray(step, jnp.float32) / every)
        return base * decay ** k
    return sched


# ---------------------------------------------------------------------------
# Gradient transforms
# ---------------------------------------------------------------------------

def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Params, max_norm: float) -> Params:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree)


# ---------------------------------------------------------------------------
# SGD (baseline / tests)
# ---------------------------------------------------------------------------

def sgd(lr: LR, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
            new = jax.tree.map(lambda p, m: p - lr_t * m, params, mom)
            return new, {"step": step, "mom": mom}
        new = jax.tree.map(lambda p, g: p - lr_t * g, params, grads)
        return new, {"step": step, "mom": None}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

def adamw(lr: LR, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, clip_norm: Optional[float] = None) -> Optimizer:
    """AdamW. With ``weight_decay=0`` this is the paper's Adam (Table 3 uses
    Adam with L2-style weight decay 1e-5 for NN2; we apply it decoupled, which
    for these magnitudes is equivalent in effect)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(params, grads, state):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1t
            vh = v / b2t
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)


def adam(lr: LR, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; first moment optional)
# ---------------------------------------------------------------------------

def adafactor(lr: LR, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, min_dim_size_to_factor: int = 128,
              momentum: Optional[float] = None,
              momentum_dtype: jnp.dtype = jnp.bfloat16) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018). Factors the second-moment of any
    matrix whose trailing two dims both exceed ``min_dim_size_to_factor`` into
    row/col statistics. Memory per factored param ~= O(rows+cols), which is
    what lets the llama3-405b training cell fit v5e HBM (DESIGN.md §4).
    ``momentum=None`` disables the first moment entirely (maximum savings);
    otherwise it is kept in ``momentum_dtype``."""

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def per(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    "v": None,
                }
            return {"vr": None, "vc": None, "v": jnp.zeros_like(p, jnp.float32)}
        state = {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(per, params, is_leaf=lambda x: isinstance(x, jnp.ndarray) or hasattr(x, "shape")),
        }
        if momentum is not None:
            state["m"] = jax.tree.map(lambda p: jnp.zeros_like(p, momentum_dtype), params)
        return state

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, vs, m):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if vs["v"] is None:
                vr = beta2 * vs["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vs["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(vr[..., :, None] * vc[..., None, :]
                                 / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps))
                new_vs = {"vr": vr, "vc": vc, "v": None}
            else:
                v = beta2 * vs["v"] + (1 - beta2) * g2
                denom = jnp.sqrt(v)
                new_vs = {"vr": None, "vc": None, "v": v}
            u = g / jnp.maximum(denom, eps)
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if m is not None:
                mm = (momentum * m.astype(jnp.float32) + (1 - momentum) * u)
                u = mm
                new_m = mm.astype(momentum_dtype)
            else:
                new_m = None
            new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return new_p, new_vs, new_m

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_m = treedef.flatten_up_to(state["m"]) if momentum is not None else [None] * len(flat_p)
        out = [upd(p, g, v, m) for p, g, v, m in zip(flat_p, flat_g, flat_v, flat_m)]
        new_state = {"step": step, "v": treedef.unflatten([o[1] for o in out])}
        if momentum is not None:
            new_state["m"] = treedef.unflatten([o[2] for o in out])
        return treedef.unflatten([o[0] for o in out]), new_state

    return Optimizer(init, update)


OPTIMIZERS = {
    "sgd": sgd,
    "adam": adam,
    "adamw": adamw,
    "adafactor": adafactor,
}


def make_optimizer(name: str, lr: LR, **kw) -> Optimizer:
    if name not in OPTIMIZERS:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(OPTIMIZERS)}")
    return OPTIMIZERS[name](lr, **kw)
