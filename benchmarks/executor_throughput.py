"""Executor throughput: interpreted per-layer dispatch vs the compiled
whole-graph batched plan (repro.primitives.plan), across executable CNN-zoo
networks and request batch sizes.

The interpreted path issues ~2xN jitted Python-level dispatches per image
(one per primitive, one per materialised DLT, each synchronised); the
compiled plan is ONE dispatch per request batch with DLTs fused into their
consumers. This benchmark measures both on warm (steady-state) repeats and
writes ``BENCH_executor.json`` with per-network interpreted/compiled timings
and images/s per batch size.

Exits nonzero if the compiled plan is *slower* than the interpreted path on
the warm measurement for a gate network — the CI smoke gate (``--smoke``)
that keeps the compiled path a strict win on every PR. Gate networks are the
dispatch-bound ones (``GATE_NETS``) where the compiled plan's advantage is
structural; 224²-scale networks saturate this container's CPU on compute, so
their compiled-vs-interpreted ratio is parity-within-noise (DESIGN.md §6) —
they are measured and recorded but not gated. All paths and batch sizes are
timed round-robin in one loop so scheduler noise hits every measurement
window alike.

Run:  PYTHONPATH=src:. python benchmarks/executor_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.models import cnn_zoo
from repro.primitives.executor import execute, make_weights
from repro.primitives.plan import (compile_plan, fused_dlt_count,
                                   heuristic_assignment)

OUT_PATH = os.environ.get("REPRO_BENCH_EXECUTOR_JSON", "BENCH_executor.json")

FULL_NETS = ("edge_cnn", "squeezenet", "alexnet")
SMOKE_NETS = ("edge_cnn",)
GATE_NETS = ("edge_cnn",)          # dispatch-bound: compiled must win warm


def _warm_round_robin_s(fns: List, repeats: int) -> List[float]:
    """Best-of-repeats (timeit-style) for several paths measured round-robin
    in one loop: a scheduler hiccup on a shared container lands inside every
    path's window equally, so the compiled-vs-interpreted *ratios* are fair."""
    samples: List[List[float]] = [[] for _ in fns]
    for _ in range(repeats):
        for j, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            samples[j].append(time.perf_counter() - t0)
    return [float(np.min(s)) for s in samples]


def bench_net(net: str, batches: List[int], repeats: int) -> Dict:
    from repro.service.pipeline import OptimisedNetwork
    from repro.service.server import OptimisedServer

    spec = cnn_zoo.get(net)
    asg = heuristic_assignment(spec)
    weights = make_weights(spec)
    n0 = spec.nodes[0]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n0.c, n0.im, n0.im)), jnp.float32)
    sink = len(spec.nodes) - 1

    # -- warm all three paths, then time everything round-robin ------------
    execute(spec, asg, weights, x=x, compiled=False)           # warm jit cache
    plan = compile_plan(spec, asg, (batches[0], n0.c, n0.im, n0.im))
    eliminated, inlined = fused_dlt_count(plan.steps)
    fns = [lambda: jax.block_until_ready(
        execute(spec, asg, weights, x=x, compiled=False).outputs[sink])]
    for b in batches:
        xb = jnp.asarray(rng.standard_normal((b, n0.c, n0.im, n0.im)), jnp.float32)
        jax.block_until_ready(plan(xb, weights)[plan.sinks[-1]])   # warm
        fns.append(lambda xb=xb: jax.block_until_ready(
            plan(xb, weights)[plan.sinks[-1]]))

    # served path: the same plan dispatched through the serving front end's
    # queue — quantifies the queue/pad/ticket overhead on top of the raw plan
    b0 = batches[0]
    server = OptimisedServer(max_batch=b0, latency_budget_ms=float("inf"))
    server.register(OptimisedNetwork.from_assignment(spec, asg),
                    weights=weights)
    xs_served = rng.standard_normal((b0, n0.c, n0.im, n0.im)).astype(np.float32)
    server.serve(net, xs_served)                               # warm
    fns.append(lambda: server.serve(net, xs_served))
    times = _warm_round_robin_s(fns, repeats)
    served_s = times.pop()

    interp_s = times[0]
    emit(f"executor.{net}.interpreted_us", interp_s * 1e6,
         f"{1.0/interp_s:.1f} img/s nodes={len(spec.nodes)}")
    compiled = {}
    for b, dt in zip(batches, times[1:]):
        compiled[b] = {"seconds_per_dispatch": dt, "images_per_s": b / dt}
        emit(f"executor.{net}.compiled_b{b}_us", dt * 1e6,
             f"{b/dt:.1f} img/s speedup={b*interp_s/dt:.1f}x")

    # per-image speedup at the base batch (interpreted serves b images as
    # b sequential dispatches) — the gate metric
    speedup_base = b0 * interp_s / compiled[b0]["seconds_per_dispatch"]
    speedup_best = max(c["images_per_s"] * interp_s for c in compiled.values())
    emit(f"executor.{net}.served_b{b0}_us", served_s * 1e6,
         f"{b0/served_s:.1f} img/s via OptimisedServer")
    return {
        "nodes": len(spec.nodes),
        "dlt_edges": {"eliminated_identity": eliminated, "inlined_transpose": inlined},
        "interpreted_per_image_s": interp_s,
        "compiled": {str(b): c for b, c in compiled.items()},
        "served": {"batch": b0, "seconds_per_dispatch": served_s,
                   "images_per_s": b0 / served_s,
                   "overhead_vs_compiled_pct": 100.0 * (
                       served_s / compiled[b0]["seconds_per_dispatch"] - 1.0)},
        "base_batch": b0,
        "warm_speedup_base": speedup_base,
        "warm_speedup_best": speedup_best,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small net set / fewer repeats (CI gate)")
    ap.add_argument("--nets", nargs="*", default=None)
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args()

    nets = tuple(args.nets) if args.nets else (SMOKE_NETS if args.smoke else FULL_NETS)
    batches = args.batches or ([1, 8] if args.smoke else [1, 8, 16])
    repeats = args.repeats or (5 if args.smoke else 9)

    results = {"mode": "smoke" if args.smoke else "full", "networks": {}}
    failures = []
    for net in nets:
        if net not in cnn_zoo.EXECUTABLE_NETS:
            raise SystemExit(f"{net} is a profile-only pool contributor, not executable")
        r = bench_net(net, list(batches), repeats)
        results["networks"][net] = r
        # gate: on dispatch-bound nets the compiled plan must not be slower
        # than interpreted warm (10% band absorbs residual timer noise)
        if net in GATE_NETS and r["warm_speedup_base"] < 0.9:
            failures.append(net)

    results["max_warm_speedup"] = max(
        r["warm_speedup_best"] for r in results["networks"].values())
    with open(OUT_PATH, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {OUT_PATH} (max warm speedup {results['max_warm_speedup']:.1f}x)")

    if failures:
        print(f"FAIL: compiled plan slower than interpreted (warm) on: {failures}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
