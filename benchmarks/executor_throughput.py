"""Executor throughput: interpreted per-layer dispatch vs the compiled
whole-graph batched plan (repro.primitives.plan), across executable CNN-zoo
networks and request batch sizes.

The interpreted path issues ~2xN jitted Python-level dispatches per image
(one per primitive, one per materialised DLT, each synchronised); the
compiled plan is ONE dispatch per request batch with DLTs fused into their
consumers. This benchmark measures both on warm (steady-state) repeats and
writes ``BENCH_executor.json`` with per-network interpreted/compiled timings
and images/s per batch size, plus three PR-9 rows (DESIGN.md §13):

* ``epilogue_fusion`` — the epilogue-fused plan vs the same assignment with
  fusion off, outputs checked tolerance-equal;
* ``served`` — the OptimisedServer dispatch path vs the raw compiled plan,
  with p50/p99 dispatch overhead from interleaved sampling;
* ``tile_variant`` (gate nets) — the PBQP-selected tile-variant assignment
  executed vs the same bases pinned to the family-default tiles.

Exits nonzero when a gate fails on a gate network (``GATE_NETS`` — the
dispatch-bound ones where each advantage is structural; 224²-scale networks
saturate this container's CPU on compute, so their ratios are
parity-within-noise (DESIGN.md §6) — measured and recorded but not gated):

* compiled plan slower than interpreted warm (``warm_speedup_base`` < 0.9);
* epilogue-fused plan below ``GATE_FUSED_RATIO`` x the unfused plan, or
  fused/unfused outputs not tolerance-equal;
* served dispatch overhead above ``GATE_OVERHEAD_PCT`` (was ~55% before the
  §13.3 fast path);
* selected-tile throughput below ``GATE_TILE_RATIO`` x the default-tile
  assignment.

All paths and batch sizes are timed round-robin in one loop so scheduler
noise hits every measurement window alike; the ratio gates carry small noise
bands for the same reason.

Run:  PYTHONPATH=src:. python benchmarks/executor_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.models import cnn_zoo
from repro.primitives.executor import execute, make_weights
from repro.primitives.plan import (compile_plan, fused_dlt_count,
                                   heuristic_assignment)

OUT_PATH = os.environ.get("REPRO_BENCH_EXECUTOR_JSON", "BENCH_executor.json")

FULL_NETS = ("edge_cnn", "squeezenet", "alexnet")
SMOKE_NETS = ("edge_cnn",)
GATE_NETS = ("edge_cnn",)          # dispatch-bound: compiled must win warm

GATE_OVERHEAD_PCT = 25.0           # served-vs-compiled ceiling (gate nets)
GATE_FUSED_RATIO = 0.97            # fused must be >= 0.97x unfused speed
GATE_TILE_RATIO = 0.95             # selected tiles >= 0.95x default tiles
EQ_TOL = 2e-3                      # fused-vs-unfused output tolerance


def _warm_round_robin_s(fns: List, repeats: int) -> Tuple[List[float],
                                                          List[List[float]]]:
    """Best-of-repeats (timeit-style) for several paths measured round-robin
    in one loop: a scheduler hiccup on a shared container lands inside every
    path's window equally, so the cross-path *ratios* are fair. Returns the
    per-path minima plus the raw sample lists (percentile reporting)."""
    samples: List[List[float]] = [[] for _ in fns]
    for _ in range(repeats):
        for j, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            samples[j].append(time.perf_counter() - t0)
    return [float(np.min(s)) for s in samples], samples


def bench_net(net: str, batches: List[int], repeats: int) -> Dict:
    from repro.service.pipeline import OptimisedNetwork
    from repro.service.server import OptimisedServer

    spec = cnn_zoo.get(net)
    asg = heuristic_assignment(spec)
    weights = make_weights(spec)
    n0 = spec.nodes[0]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n0.c, n0.im, n0.im)), jnp.float32)
    sink = len(spec.nodes) - 1
    b0 = batches[0]

    # -- warm all paths, then time everything round-robin ------------------
    execute(spec, asg, weights, x=x, compiled=False)           # warm jit cache
    plan = compile_plan(spec, asg, (b0, n0.c, n0.im, n0.im))   # fused (default)
    unfused = compile_plan(spec, asg, (b0, n0.c, n0.im, n0.im),
                           epilogues=False)
    eliminated, inlined = fused_dlt_count(plan.steps)
    fns = [lambda: jax.block_until_ready(
        execute(spec, asg, weights, x=x, compiled=False).outputs[sink])]
    for b in batches:
        xb = jnp.asarray(rng.standard_normal((b, n0.c, n0.im, n0.im)), jnp.float32)
        jax.block_until_ready(plan(xb, weights)[plan.sinks[-1]])   # warm
        fns.append(lambda xb=xb: jax.block_until_ready(
            plan(xb, weights)[plan.sinks[-1]]))
    xb0 = jnp.asarray(rng.standard_normal((b0, n0.c, n0.im, n0.im)), jnp.float32)
    y_fused = np.asarray(jax.block_until_ready(
        plan(xb0, weights)[plan.sinks[-1]]))
    y_unfused = np.asarray(jax.block_until_ready(
        unfused(xb0, weights)[unfused.sinks[-1]]))
    outputs_equal = bool(np.allclose(y_fused, y_unfused,
                                     rtol=EQ_TOL, atol=EQ_TOL))
    fns.append(lambda: jax.block_until_ready(
        unfused(xb0, weights)[unfused.sinks[-1]]))

    # served path: the same plan dispatched through the serving front end's
    # queue — quantifies the queue/pad/ticket overhead on top of the raw plan
    server = OptimisedServer(max_batch=b0, latency_budget_ms=float("inf"))
    server.register(OptimisedNetwork.from_assignment(spec, asg),
                    weights=weights)
    xs_served = rng.standard_normal((b0, n0.c, n0.im, n0.im)).astype(np.float32)
    server.serve(net, xs_served)                               # warm
    fns.append(lambda: server.serve(net, xs_served))
    times, samples = _warm_round_robin_s(fns, repeats)
    served_s = times.pop()
    unfused_s = times.pop()

    # the overhead gate compares MATCHED PAIRS: each loop turn runs one raw
    # plan dispatch then one served dispatch back to back, so machine drift
    # and cache state hit both alike. (The round-robin mins above are NOT
    # matched — there a serve sample runs cold after five other heavy
    # paths, while a bare plan call has almost no Python state to cool —
    # so they serve as the throughput numbers, not the overhead gate.)
    extra = max(4 * repeats, 48)
    comp_samp, served_samp = [], []
    for _ in range(extra):
        t0 = time.perf_counter()
        jax.block_until_ready(plan(xb0, weights)[plan.sinks[-1]])
        t1 = time.perf_counter()
        server.serve(net, xs_served)
        t2 = time.perf_counter()
        comp_samp.append(t1 - t0)
        served_samp.append(t2 - t1)
    comp_p50 = float(np.percentile(comp_samp, 50))
    served_p50 = float(np.percentile(served_samp, 50))
    served_p99 = float(np.percentile(served_samp, 99))

    interp_s = times[0]
    emit(f"executor.{net}.interpreted_us", interp_s * 1e6,
         f"{1.0/interp_s:.1f} img/s nodes={len(spec.nodes)}")
    compiled = {}
    for b, dt in zip(batches, times[1:]):
        compiled[b] = {"seconds_per_dispatch": dt, "images_per_s": b / dt}
        emit(f"executor.{net}.compiled_b{b}_us", dt * 1e6,
             f"{b/dt:.1f} img/s speedup={b*interp_s/dt:.1f}x")

    # per-image speedup at the base batch (interpreted serves b images as
    # b sequential dispatches) — the gate metric
    fused_s = compiled[b0]["seconds_per_dispatch"]
    speedup_base = b0 * interp_s / fused_s
    speedup_best = max(c["images_per_s"] * interp_s for c in compiled.values())
    overhead_pct = 100.0 * (served_p50 / comp_p50 - 1.0)
    emit(f"executor.{net}.served_b{b0}_us", served_s * 1e6,
         f"{b0/served_s:.1f} img/s overhead={overhead_pct:.1f}% "
         f"p50={served_p50*1e6:.0f}us p99={served_p99*1e6:.0f}us")
    emit(f"executor.{net}.fused_vs_unfused", unfused_s / fused_s,
         f"sig={list(plan.epilogue_signature)} equal={outputs_equal}")
    return {
        "nodes": len(spec.nodes),
        "dlt_edges": {"eliminated_identity": eliminated, "inlined_transpose": inlined},
        "interpreted_per_image_s": interp_s,
        "compiled": {str(b): c for b, c in compiled.items()},
        "epilogue_fusion": {
            "batch": b0,
            "signature": [list(e) for e in plan.epilogue_signature],
            "fused_seconds_per_dispatch": fused_s,
            "unfused_seconds_per_dispatch": unfused_s,
            "fused_over_unfused_speed": unfused_s / fused_s,
            "strictly_faster": bool(fused_s < unfused_s),
            "outputs_equal": outputs_equal,
        },
        "served": {"batch": b0, "seconds_per_dispatch": served_s,
                   "images_per_s": b0 / served_s,
                   "overhead_vs_compiled_pct": overhead_pct,
                   "p50_seconds_per_dispatch": served_p50,
                   "p99_seconds_per_dispatch": served_p99,
                   "p50_overhead_pct": 100.0 * (served_p50 / comp_p50 - 1.0),
                   "p99_overhead_pct": 100.0 * (served_p99 / comp_p50 - 1.0)},
        "base_batch": b0,
        "warm_speedup_base": speedup_base,
        "warm_speedup_best": speedup_best,
    }


def _default_tile(column: str) -> str:
    """The same base pinned to its kernel family's default tile."""
    from repro.primitives.conv import split_tile
    base, variant = split_tile(column)
    if variant is None:
        return column
    if variant.startswith("conv-bk"):
        return f"{base}@conv-bk128"
    if variant.startswith("wino-"):
        return f"{base}@wino-128x128"
    return f"{base}@mm-128x128x128"


def bench_tile_variant(net: str, b: int, repeats: int,
                       max_iters: int) -> Optional[Dict]:
    """Execute the PBQP-selected tile-variant assignment vs the same bases
    on the family-default tiles (DESIGN.md §13.1): the selected plan's
    throughput must not lose to the fixed default — the perf model prices
    the blocks the kernels actually run with. Returns None when selection
    picked no tile columns (nothing to compare)."""
    from repro.service.pipeline import optimise
    from repro.service.platforms import PallasPlatform, get_platform

    spec = cnn_zoo.get(net)
    tpu = PallasPlatform(max_triplets=5)
    base = get_platform("intel", max_triplets=5).pretrain(
        max_iters=max_iters, patience=40)
    models = tpu.calibrate(base, budget=0.05, max_iters=max_iters)
    opt = optimise(spec, tpu, models=models, executable=True)
    selected = opt.assignment
    tiled = {i: v for i, v in selected.items() if "@" in v}
    if not tiled:
        return None
    default = {i: _default_tile(v) for i, v in selected.items()}

    weights = make_weights(spec)
    n0 = spec.nodes[0]
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.standard_normal((b, n0.c, n0.im, n0.im)), jnp.float32)
    shape = (b, n0.c, n0.im, n0.im)
    p_sel = compile_plan(spec, selected, shape)
    p_def = compile_plan(spec, default, shape)
    jax.block_until_ready(p_sel(xb, weights)[p_sel.sinks[-1]])     # warm
    jax.block_until_ready(p_def(xb, weights)[p_def.sinks[-1]])
    times, _ = _warm_round_robin_s(
        [lambda: jax.block_until_ready(p_sel(xb, weights)[p_sel.sinks[-1]]),
         lambda: jax.block_until_ready(p_def(xb, weights)[p_def.sinks[-1]])],
        repeats)
    sel_ips, def_ips = b / times[0], b / times[1]
    emit(f"executor.{net}.tile_selected_vs_default", sel_ips / def_ips,
         f"{sel_ips:.1f} vs {def_ips:.1f} img/s tiles={len(tiled)}")
    return {
        "batch": b,
        "tile_columns_selected": len(tiled),
        "selected_assignment": {str(i): v for i, v in sorted(tiled.items())},
        "selected_images_per_s": sel_ips,
        "default_images_per_s": def_ips,
        "selected_over_default": sel_ips / def_ips,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small net set / fewer repeats (CI gate)")
    ap.add_argument("--nets", nargs="*", default=None)
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args()

    nets = tuple(args.nets) if args.nets else (SMOKE_NETS if args.smoke else FULL_NETS)
    batches = args.batches or ([1, 8] if args.smoke else [1, 8, 16])
    repeats = args.repeats or (5 if args.smoke else 9)

    results = {"mode": "smoke" if args.smoke else "full", "networks": {}}
    failures = []
    for net in nets:
        if net not in cnn_zoo.EXECUTABLE_NETS:
            raise SystemExit(f"{net} is a profile-only pool contributor, not executable")
        r = bench_net(net, list(batches), repeats)
        results["networks"][net] = r
        if net not in GATE_NETS:
            continue
        # gate: on dispatch-bound nets the compiled plan must not be slower
        # than interpreted warm (10% band absorbs residual timer noise)
        if r["warm_speedup_base"] < 0.9:
            failures.append(f"{net}: compiled slower than interpreted "
                            f"({r['warm_speedup_base']:.2f}x)")
        ef = r["epilogue_fusion"]
        if not ef["outputs_equal"]:
            failures.append(f"{net}: fused and unfused outputs differ")
        if ef["fused_over_unfused_speed"] < GATE_FUSED_RATIO:
            failures.append(f"{net}: epilogue-fused plan too slow "
                            f"({ef['fused_over_unfused_speed']:.3f}x unfused)")
        if r["served"]["overhead_vs_compiled_pct"] > GATE_OVERHEAD_PCT:
            failures.append(
                f"{net}: served dispatch overhead "
                f"{r['served']['overhead_vs_compiled_pct']:.1f}% > "
                f"{GATE_OVERHEAD_PCT:.0f}%")
        tv = bench_tile_variant(net, batches[0],
                                repeats, max_iters=120 if args.smoke else 200)
        if tv is None:
            failures.append(f"{net}: selection chose no tile columns")
        else:
            r["tile_variant"] = tv
            if tv["selected_over_default"] < GATE_TILE_RATIO:
                failures.append(
                    f"{net}: selected tiles slower than default "
                    f"({tv['selected_over_default']:.3f}x)")

    results["max_warm_speedup"] = max(
        r["warm_speedup_best"] for r in results["networks"].values())
    results["any_epilogue_strictly_faster"] = any(
        r["epilogue_fusion"]["strictly_faster"]
        for r in results["networks"].values())
    with open(OUT_PATH, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {OUT_PATH} (max warm speedup {results['max_warm_speedup']:.1f}x)")

    if failures:
        print("FAIL:\n  " + "\n  ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
