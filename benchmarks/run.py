"""Benchmark harness — one function per paper table/figure (+ roofline and
the TPU autotune feature). Prints ``name,us_per_call,derived`` CSV.

Set REPRO_BENCH_FAST=1 for a reduced-size pass.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (autotune_tpu, dlt_accuracy, perfmodel_accuracy,
                            real_cpu_pipeline, roofline, selection_quality,
                            selection_speed, transfer_factor,
                            transfer_families, transfer_finetune)
    suites = [
        ("fig4/5 perf-model accuracy", perfmodel_accuracy),
        ("fig6 DLT accuracy", dlt_accuracy),
        ("table4 selection speed", selection_speed),
        ("fig7 selection quality", selection_quality),
        ("fig8 factor transfer", transfer_factor),
        ("fig9/10 fine-tune transfer", transfer_finetune),
        ("table5 family transfer", transfer_families),
        ("real-CPU pipeline", real_cpu_pipeline),
        ("TPU kernel autotune", autotune_tpu),
        ("roofline (dry-run artifacts)", roofline),
    ]
    failures = 0
    for name, mod in suites:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# ({name}: {time.time()-t0:.1f}s)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
