"""Paper Figs 9/10: fine-tuning the Intel model to AMD/ARM vs training from
scratch, across training-data fractions — through the service layer's
calibrate path (repro.service.platforms), with ground-truth scoring over a
prebuilt PBQP graph (one O(build), many evaluations)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (FAST, emit, platform as sim_platform, store,
                               trained_model)
from repro.core.selection import build_pbqp, network_cost, select
from repro.models import cnn_zoo

FRACTIONS = (0.001, 0.01, 0.1, 0.25) if not FAST else (0.01, 0.1)
SEEDS = (0, 1) if not FAST else (0,)


def main() -> dict:
    results = {}
    intel = trained_model("nn2", "intel")
    spec = cnn_zoo.get("googlenet")
    for plat in ("amd", "arm"):
        platform = sim_platform(plat)
        ds = platform.primitive_dataset()
        _, _, te = ds.split()
        truth = platform.cost_provider()
        g_truth = build_pbqp(spec, truth)    # one build, many evaluations
        c_opt = select(spec, truth).solver_cost
        full = trained_model("nn2", plat)
        results[f"{plat}.full"] = full.mdrae(te.feats, te.times)
        for frac in FRACTIONS:
            for mode in ("scratch", "finetune"):
                errs, incs = [], []
                for seed in SEEDS:
                    cal = platform.calibrate(
                        intel, frac, mode=mode, store=store(), seed=seed,
                        dlt_kind="nn2",
                        dlt_max_iters=8000 if not FAST else 2000,
                        max_iters=2000 if not FAST else 1200)
                    errs.append(cal.prim.mdrae(te.feats, te.times))
                    sel = select(spec, cal.provider())
                    c = network_cost(spec, sel.assignment, graph=g_truth)
                    incs.append(100.0 * (c / c_opt - 1.0))
                md, inc = float(np.mean(errs)), float(np.mean(incs))
                results[f"{plat}.{mode}.{frac}"] = {"mdrae": md, "increase_pct": inc}
                emit(f"fig9.{plat}.{mode}.frac{frac}", md * 100,
                     f"mdrae={md*100:.1f}% increase={inc:.2f}%")
    return results


if __name__ == "__main__":
    main()
