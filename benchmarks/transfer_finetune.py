"""Paper Figs 9/10: fine-tuning the Intel model to AMD/ARM vs training from
scratch, across training-data fractions."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import FAST, dataset, dlt_dataset, emit, trained_model
from repro.core.perfmodel import fit_perf_model
from repro.core.selection import ModelProvider, SimulatedProvider, network_cost, select
from repro.models import cnn_zoo

FRACTIONS = (0.001, 0.01, 0.1, 0.25) if not FAST else (0.01, 0.1)
SEEDS = (0, 1) if not FAST else (0,)


def main() -> dict:
    results = {}
    intel = trained_model("intel_nn2", "nn2", dataset("intel"))
    spec = cnn_zoo.get("googlenet")
    for plat in ("amd", "arm"):
        ds = dataset(plat)
        tr, va, te = ds.split()
        truth = SimulatedProvider(plat)
        c_opt = select(spec, truth).solver_cost
        dlt_native = trained_model(f"{plat}_dlt_nn2", "nn2", dlt_dataset(plat))
        full = trained_model(f"{plat}_nn2", "nn2", ds)
        results[f"{plat}.full"] = full.mdrae(te.feats, te.times)
        for frac in FRACTIONS:
            for mode in ("scratch", "finetune"):
                errs, incs = [], []
                for seed in SEEDS:
                    sub = tr.subsample(frac, seed=seed)
                    m = fit_perf_model(
                        "nn2", sub.feats, sub.times, va.feats, va.times,
                        columns=ds.columns, seed=seed,
                        base=intel if mode == "finetune" else None,
                        max_iters=2000 if not FAST else 1200, patience=150)
                    errs.append(m.mdrae(te.feats, te.times))
                    prov = ModelProvider(m, dlt_native)
                    c = network_cost(spec, select(spec, prov).assignment, truth)
                    incs.append(100.0 * (c / c_opt - 1.0))
                md, inc = float(np.mean(errs)), float(np.mean(incs))
                results[f"{plat}.{mode}.{frac}"] = {"mdrae": md, "increase_pct": inc}
                emit(f"fig9.{plat}.{mode}.frac{frac}", md * 100,
                     f"mdrae={md*100:.1f}% increase={inc:.2f}%")
    return results


if __name__ == "__main__":
    main()
