"""End-to-end selection throughput: the profile→estimate→select hot path.

Measures (a) cost-matrix + PBQP-graph construction for a VGG-19-scale spec
through the seed's scalar per-(layer, primitive) path versus the vectorised
batch path (identical inputs, numerically identical graphs — see
tests/test_batch_equivalence.py), and (b) steady-state full selections per
second (estimate + build + solve) over the CNN zoo with the batch path.

Writes ``BENCH_selection.json`` — the repo's first perf trajectory point —
with both the seed-equivalent scalar timing and the new batched timing.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import emit
from repro.core import pbqp
from repro.core.selection import (SimulatedProvider, _DLT_COLS, _edge_tensor,
                                  _in_layout, _node_choices, _out_layout,
                                  build_pbqp, select)
from repro.models import cnn_zoo
from repro.models.cnn_zoo import CNNSpec, ConvLayer
from repro.primitives import layouts as L
from repro.primitives.conv import PRIMITIVE_NAMES, REGISTRY
from repro.profiler.simulators import (PLATFORMS, _dlt_time_scalar,
                                       _primitive_time_scalar)

OUT_PATH = os.environ.get("REPRO_BENCH_SELECTION_JSON", "BENCH_selection.json")


class ScalarSimulatedProvider:
    """Seed-equivalent provider: one scalar model call per (layer, primitive)
    cell and per (pair, DLT) cell — the pre-vectorisation baseline."""

    def __init__(self, platform: str, noisy: bool = True):
        self._plat = PLATFORMS[platform]
        self.noisy = noisy
        self.columns = list(PRIMITIVE_NAMES)

    def primitive_cost_matrix(self, configs: np.ndarray) -> np.ndarray:
        out = np.full((len(configs), len(self.columns)), np.nan)
        for i, (k, c, im, s, f) in enumerate(np.asarray(configs, int)):
            for j, name in enumerate(self.columns):
                out[i, j] = _primitive_time_scalar(
                    self._plat, REGISTRY[name], k, c, im, s, f, noisy=self.noisy)
        return out

    def dlt_cost_matrix(self, pairs: np.ndarray) -> np.ndarray:
        out = np.zeros((len(pairs), len(_DLT_COLS)))
        for i, (c, im) in enumerate(np.asarray(pairs, int)):
            j = 0
            for (s, d) in L.dlt_pairs():
                if s == d:
                    continue
                out[i, j] = _dlt_time_scalar(self._plat, s, d, int(c), int(im),
                                             noisy=self.noisy)
                j += 1
        return out


def build_pbqp_scalar(spec: CNNSpec, provider) -> pbqp.PBQPGraph:
    """Seed-equivalent graph construction: Python loop over every
    (producer choice, consumer choice) pair of every edge."""
    columns = list(provider.columns)
    convs = [(i, n) for i, n in enumerate(spec.nodes) if isinstance(n, ConvLayer)]
    configs = np.array([n.config for _, n in convs], np.float64)
    cost_mat = (provider.primitive_cost_matrix(configs)
                if len(convs) else np.zeros((0, len(columns))))
    pair_list = sorted({_edge_tensor(spec.nodes[u]) for (u, v) in spec.edges})
    pair_idx = {p: i for i, p in enumerate(pair_list)}
    dlt_mat = (provider.dlt_cost_matrix(np.array(pair_list, np.float64))
               if pair_list else np.zeros((0, len(_DLT_COLS))))
    dlt_col = {name: j for j, name in enumerate(_DLT_COLS)}

    def dlt(src, dst, c, im):
        if src == dst:
            return 0.0
        return float(max(dlt_mat[pair_idx[(c, im)], dlt_col[L.dlt_name(src, dst)]], 0.0))

    g = pbqp.PBQPGraph()
    conv_cost = {i: cost_mat[r] for r, (i, _) in enumerate(convs)}
    for i, node in enumerate(spec.nodes):
        choices = _node_choices(node, columns)
        if isinstance(node, ConvLayer):
            vec = np.maximum(np.where(np.isfinite(conv_cost[i]),
                                      conv_cost[i], np.inf), 0.0)
        else:
            vec = np.zeros(len(choices))
        g.add_node(i, vec, labels=choices)
    for (u, v) in spec.edges:
        nu, nv = spec.nodes[u], spec.nodes[v]
        cu, cv = _node_choices(nu, columns), _node_choices(nv, columns)
        c, im = _edge_tensor(nu)
        m = np.zeros((len(cu), len(cv)))
        for a, pa in enumerate(cu):
            for b, pb in enumerate(cv):
                m[a, b] = dlt(_out_layout(nu, pa), _in_layout(nv, pb), c, im)
        g.add_edge(u, v, m)
    return g


def _median_seconds(fn, repeats: int) -> float:
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def main() -> Dict:
    platform = "intel"
    spec = cnn_zoo.get("vgg19")

    # -- (a) cost-matrix + graph construction: scalar seed path vs batched --
    scalar_prov = ScalarSimulatedProvider(platform)
    batch_prov = SimulatedProvider(platform)
    build_pbqp(spec, batch_prov)                   # warm caches (traits etc.)
    scalar_s = _median_seconds(lambda: build_pbqp_scalar(spec, scalar_prov), 3)
    batched_s = _median_seconds(lambda: build_pbqp(spec, batch_prov), 9)
    speedup = scalar_s / batched_s
    emit("selection.vgg19.build_scalar_us", scalar_s * 1e6, "seed-equivalent path")
    emit("selection.vgg19.build_batched_us", batched_s * 1e6,
         f"vectorised path speedup={speedup:.1f}x")

    # -- (b) steady-state full selections/second over the CNN zoo ----------
    nets = {}
    total_s = 0.0
    for net in sorted(cnn_zoo.ZOO):
        sp = cnn_zoo.get(net)
        select(sp, batch_prov)                     # warm
        sel_s = _median_seconds(lambda: select(sp, batch_prov), 5)
        nets[net] = {"select_s": sel_s, "selections_per_s": 1.0 / sel_s,
                     "nodes": len(sp.nodes), "edges": len(sp.edges)}
        total_s += sel_s
        emit(f"selection.{net}.select_us", sel_s * 1e6,
             f"{1.0 / sel_s:.1f} selections/s nodes={len(sp.nodes)}")
    zoo_rate = len(nets) / total_s
    emit("selection.zoo.mean_select_us", total_s / len(nets) * 1e6,
         f"{zoo_rate:.1f} selections/s over {len(nets)} networks")

    results = {
        "platform": platform,
        "vgg19_build": {
            "scalar_seed_equivalent_s": scalar_s,
            "batched_s": batched_s,
            "speedup": speedup,
        },
        "zoo_selection": {
            "networks": nets,
            "mean_select_s": total_s / len(nets),
            "selections_per_s": zoo_rate,
        },
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {OUT_PATH} (vgg19 build speedup {speedup:.1f}x, "
          f"{zoo_rate:.1f} selections/s)")
    return results


if __name__ == "__main__":
    main()
