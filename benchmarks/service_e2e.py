"""Service-layer end to end: cold vs warm optimise time, served img/s,
concurrent multi-network serving vs the serial pump baseline, zero-cost
drift recalibration from served traffic, predicted-cost cross-backend
routing, deadline-aware batch windows, and availability under injected
faults (DESIGN.md §11).

Cold pass: a fresh artifact store — pretrain the base platform model,
calibrate onto the target platform, PBQP-select. Warm pass: identical calls
against the now-populated store — every model and the selection must come
back from disk, selecting the *same assignment*, ≥10x faster (the paper's
Table 4 "seconds, not hours" claim as a regression gate). Then the optimised
network is served through ``OptimisedServer`` for a throughput figure, and a
multi-network load (optimised + fixed-primitive variants of the net) is
served twice — synchronous ``pump()`` vs the worker-pool serving core — to
measure the concurrency win and p50/p99 queueing latency.

The recalibration row drives a drifting platform until the serving loop
detects the drift and hot-swaps a recalibration built from its OWN served
observations (DESIGN.md §8.5), then times the fresh-profiling alternative on
the same drifted platform. Profiling cost is made visible by charging each
``profile()``'d config a nominal wall-clock cost (a real device pays
repeats × runtime per config; the analytic simulator would otherwise hide
exactly the cost the served-sample path eliminates).

The fleet_recal row (DESIGN.md §14) runs the recalibration story across two
hosts sharing one simulated object-store bucket: host A drifts,
recalibrates from served traffic, and publishes the evidence under the
platform's pool fingerprint; host B warm-starts everything from the shared
bucket and hot-swaps a recalibration built from A's pooled evidence alone —
gated on zero freshly profiled configs for B and byte-identical post-swap
assignments.

The multibackend row optimises the same net for several backends against
one artifact store (per-backend selections, checked byte-reproducible on a
second warm optimise), then serves one request stream three ways: each
backend alone, and all backends registered behind one logical net with the
predicted-cost router (DESIGN.md §9) spreading batches across them. Each
backend is charged its nominal device time per image as wall-clock (same
reasoning as the recalibration row: one host CPU standing in for several
devices would hide exactly the parallelism being measured). The gate
requires routed throughput ≥ the best single backend.

The deadline row serves a paced lone-request load twice: an effectively
unbounded latency budget (batch windows run to their static cap) vs a tight
budget (windows capped at budget − predicted execution, shrunk further by
the drift monitor when observed p99 queueing exceeds the budget).

The frontend_scaling row serves the same warmed load through the thread
front end (submitter threads, GIL-bound batch assembly) and through the
multi-process shared-memory front end (DESIGN.md §12: intake processes
writing payloads once into slab buckets, workers executing zero-copy
views), equal workers, then re-drives the shm path under an injected fault
plan — the chaos accounting identity (zero lost, zero duplicated) must
hold on slabs too. The bucket_cost row serves pow2-bucket bursts of ≥ 2 zoo
nets and scores the batch-shape-aware per-image cost model
(``BucketScaleHead``, §12.3) against the batch-size-invariant linear model
on held-out served latencies; the head must be strictly more accurate on
every net.

Writes ``BENCH_service.json``. Exits nonzero if the warm pass is < 10x
faster than cold, picks a different assignment, concurrent multi-network
throughput falls below the serial baseline (parity with a 15% noise
allowance on single-core runners, where the worker pool has no hardware
to overlap on), the drift recalibration is not
mostly served-sampled (≥ 50%) and faster than fresh profiling, the fleet
row's second host fails to warm-start, profiles any config freshly, or
diverges from host A's assignment, routed
multi-backend throughput falls below the best single backend, the
deadline-aware window misses the budget on the smoke load, or the
availability row drops below 99% served / loses / duplicates tickets under
its injected raise+hang+slowdown fault plan, the process front end falls
below the thread front end (parity allowance on ≤2-core runners), the shm
chaos drive loses or duplicates tickets, or the bucket-aware cost model is
not strictly more accurate than linear on every listed net — the CI smoke
gates (``--smoke``).

Run:  PYTHONPATH=src:. python benchmarks/service_e2e.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from benchmarks.common import emit

OUT_PATH = os.environ.get("REPRO_BENCH_SERVICE_JSON", "BENCH_service.json")


def optimise_pass(store_root: str, *, net: str, platform: str, base: str,
                  max_triplets: int, max_iters: int) -> Dict:
    """One full optimise run against ``store_root``; fresh Platform objects
    so nothing is warm except what the store provides."""
    from repro.service import ArtifactStore, get_platform, optimise

    store = ArtifactStore(store_root)
    t0 = time.perf_counter()
    base_models = get_platform(base, max_triplets=max_triplets).pretrain(
        "nn2", store=store, max_iters=max_iters)
    opt = optimise(net, get_platform(platform, max_triplets=max_triplets),
                   store=store, base=base_models, mode="factor",
                   executable=True)
    seconds = time.perf_counter() - t0
    return {"seconds": seconds, "opt": opt,
            "warm": base_models.warm and opt.warm}


def serve_pass(opt, requests: int, budget_ms: float) -> Dict:
    from repro.service import OptimisedServer

    server = OptimisedServer(latency_budget_ms=budget_ms)
    server.register(opt)
    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((requests, n0.c, n0.im, n0.im)).astype(np.float32)
    server.serve(opt.net, xs)                          # warm the plan cache
    s0 = server.stats(opt.net)
    t0 = time.perf_counter()
    server.serve(opt.net, xs)
    dt = time.perf_counter() - t0
    s = server.stats(opt.net)                          # delta = timed pass only
    return {"requests": requests, "seconds": dt,
            "images_per_s": requests / dt, "batch_cap": s["batch_cap"],
            "dispatches": s["dispatches"] - s0["dispatches"],
            "padded": s["padded"] - s0["padded"]}


def _multinet_opts(opt) -> list:
    """The multi-network load: the optimised assignment plus two
    fixed-primitive variants of the same topology (an A/B serving shape —
    three models live behind one server)."""
    from repro.models.cnn_zoo import ConvLayer
    from repro.primitives.plan import heuristic_assignment
    from repro.service import OptimisedNetwork

    spec = opt.spec
    heur = OptimisedNetwork.from_assignment(
        spec, heuristic_assignment(spec), net=f"{opt.net}@heuristic",
        predicted_cost_s=opt.predicted_cost_s)
    fixed_asg = {i: ("conv-1x1-gemm-ab-ki" if getattr(n, "f", 0) == 1
                     else "direct-sum2d") if isinstance(n, ConvLayer) else "chw"
                 for i, n in enumerate(spec.nodes)}
    fixed = OptimisedNetwork.from_assignment(
        spec, fixed_asg, net=f"{opt.net}@fixed",
        predicted_cost_s=opt.predicted_cost_s)
    return [opt, heur, fixed]


def multinet_pass(opts, weights, requests_per_net: int, budget_ms: float,
                  *, workers: int, max_wait_ms: float) -> Dict:
    """Serve ``requests_per_net`` per network, submissions interleaved
    round-robin. ``workers=0`` is the serial pump baseline; ``workers>0`` the
    concurrent serving core. Returns throughput + queueing percentiles."""
    import numpy as np
    from repro.service import OptimisedServer

    server = OptimisedServer(max_batch=8, latency_budget_ms=budget_ms,
                             workers=workers, max_wait_ms=max_wait_ms,
                             queue_depth=4096)
    for o in opts:
        server.register(o, weights=weights)
    n0 = opts[0].spec.nodes[0]
    rng = np.random.default_rng(1)
    xs = rng.standard_normal(
        (requests_per_net, n0.c, n0.im, n0.im)).astype(np.float32)

    tickets = []
    t0 = time.perf_counter()
    for i in range(requests_per_net):
        for o in opts:
            tickets.append(server.submit(o.net, xs[i]))
    if workers:
        for t in tickets:
            t.wait(300.0)
    else:
        while any(not t.done for t in tickets):
            server.pump()
    dt = time.perf_counter() - t0
    # a ticket that never finished (wait timed out) is a failure too
    failed = [t for t in tickets if t.error or not t.done]
    per_net = {o.net: server.stats(o.net) for o in opts}
    server.stop()
    waits_p50 = max(s["queue_wait_p50_ms"] for s in per_net.values())
    waits_p99 = max(s["queue_wait_p99_ms"] for s in per_net.values())
    return {"workers": workers, "requests": len(tickets), "seconds": dt,
            "failed": len(failed),
            "images_per_s": len(tickets) / dt,
            "queue_wait_p50_ms": waits_p50, "queue_wait_p99_ms": waits_p99,
            "dispatches": sum(s["dispatches"] for s in per_net.values()),
            "padded": sum(s["padded"] for s in per_net.values())}


def concurrent_pass(opt, requests_per_net: int, budget_ms: float,
                    workers: int, max_wait_ms: float) -> Dict:
    """Serial-pump vs worker-pool serving of the same 3-network load."""
    from repro.primitives.executor import make_weights
    from repro.service import OptimisedServer

    opts = _multinet_opts(opt)
    weights = make_weights(opt.spec)
    # warm every (net, pow2-bucket) plan once: the global plan cache serves
    # both measured passes, so neither pays jit compile
    warm = OptimisedServer(max_batch=8, latency_budget_ms=budget_ms)
    for o in opts:
        warm.register(o, weights=weights)
    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(2)
    for o in opts:
        for b in (1, 2, 4, 8):
            warm.serve(o.net, rng.standard_normal(
                (b, n0.c, n0.im, n0.im)).astype(np.float32))

    serial = multinet_pass(opts, weights, requests_per_net, budget_ms,
                           workers=0, max_wait_ms=max_wait_ms)
    conc = multinet_pass(opts, weights, requests_per_net, budget_ms,
                         workers=workers, max_wait_ms=max_wait_ms)
    return {"networks": [o.net for o in opts], "serial": serial,
            "concurrent": conc,
            "speedup": conc["images_per_s"] / serial["images_per_s"]}


def multibackend_pass(store_root: str, *, net: str, backends, base: str,
                      max_triplets: int, max_iters: int, requests: int,
                      budget_ms: float, workers: int,
                      device_s: float = 0.012) -> Dict:
    """Cross-backend routed serving (DESIGN.md §9) vs each single backend
    alone on the same workload, with per-backend selections warm-started
    from one ``ArtifactStore`` and checked reproducible.

    Every listed backend executes on THIS host's CPU, which would hide
    exactly the device parallelism the router exploits (and on a one-core
    runner there is none to find). So, in the style of the recalibration
    row's ``ChargedPlatform``, each backend is charged a nominal device
    time per dispatched image — ``device_s`` for the first backend, halved
    per position after it — as a wall-clock sleep inside ``_run_plan``.
    Sleeps overlap across worker threads the way independent devices do,
    and the distinct speeds make the router's predicted-cost split (fast
    device gets the larger share) part of what the gate measures."""
    from repro.primitives.executor import make_weights
    from repro.service import (ArtifactStore, OptimisedServer, get_platform,
                               optimise)

    charge = {b: device_s / (2 ** i) for i, b in enumerate(backends)}

    class DeviceChargedServer(OptimisedServer):
        def _run_plan(self, o, xs, weights):
            out = super()._run_plan(o, xs, weights)
            time.sleep(charge.get(o.platform.name, 0.0) * xs.shape[0])
            return out

    store = ArtifactStore(store_root)
    base_models = get_platform(base, max_triplets=max_triplets).pretrain(
        "nn2", store=store, max_iters=max_iters)

    def optimise_backend(b):
        return optimise(net, get_platform(b, max_triplets=max_triplets),
                        store=store, base=base_models, mode="factor",
                        executable=True)

    opts = {b: optimise_backend(b) for b in backends}
    # reproducibility: a second optimise per backend must warm-load the
    # SAME assignment from the store (backend-keyed artifacts, no collision)
    rerun = {b: optimise_backend(b) for b in backends}
    repro_ok = all(rerun[b].warm and rerun[b].assignment == opts[b].assignment
                   for b in backends)

    spec = opts[backends[0]].spec
    weights = make_weights(spec)
    n0 = spec.nodes[0]
    rng = np.random.default_rng(5)
    xs = rng.standard_normal((requests, n0.c, n0.im, n0.im)).astype(np.float32)

    def timed(members) -> Dict:
        server = DeviceChargedServer(max_batch=8, latency_budget_ms=budget_ms,
                                     workers=workers, max_wait_ms=2.0,
                                     queue_depth=4096)
        for bname, o in members:
            server.register(o, weights=weights, backend=bname,
                            max_inflight=1)
        # warm every backend through its exact state key: compiles each
        # (assignment, pow2-bucket) plan AND primes the router's observed
        # per-image cost so the timed burst routes on served truth
        for bname, o in members:
            key = o.net if bname is None else f"{o.net}#{bname}"
            for k in (1, 2, 4, 8):
                server.serve(key, xs[:k])
        t0 = time.perf_counter()
        tickets = [server.submit(net, x) for x in xs]
        for t in tickets:
            t.wait(300.0)
        dt = time.perf_counter() - t0
        failed = sum(1 for t in tickets if t.error or not t.done)
        s = server.stats(net)
        server.stop()
        out = {"seconds": dt, "images_per_s": len(xs) / dt,
               "failed": failed}
        if "backends" in s:
            out["per_backend"] = {
                b: {"dispatches": bs["dispatches"], "images": bs["images"],
                    "queue_wait_p50_ms": bs["queue_wait_p50_ms"],
                    "queue_wait_p99_ms": bs["queue_wait_p99_ms"]}
                for b, bs in s["backends"].items()}
        return out

    single = {b: timed([(None, opts[b])]) for b in backends}
    routed = timed([(b, opts[b]) for b in backends])
    best = max(single, key=lambda b: single[b]["images_per_s"])
    ratio = routed["images_per_s"] / single[best]["images_per_s"]
    return {"backends": list(backends), "single": single, "routed": routed,
            "best_single": best, "routed_vs_best_single": ratio,
            "reproducible_from_store": repro_ok}


def _charged_platform(name: str, charge_s: float, max_triplets: int):
    """A SimulatedPlatform charging wall-clock per profiled config: a real
    device pays repeats × runtime for every measurement; the analytic
    simulator answering instantly would hide the cost §8.5/§14.3
    eliminate. ``profiled_configs`` counts every freshly measured config."""
    from repro.service.platforms import SimulatedPlatform

    class ChargedPlatform(SimulatedPlatform):
        def __init__(self, name, charge_s, **kw):
            super().__init__(name, **kw)
            self.charge_s = charge_s
            self.profiled_configs = 0

        def profile(self, configs):
            cfgs = np.atleast_2d(np.asarray(configs))
            self.profiled_configs += len(cfgs)
            time.sleep(self.charge_s * len(cfgs))
            return super().profile(cfgs)

    return ChargedPlatform(name, charge_s, max_triplets=max_triplets)


def _drifting_server(**kw):
    """An OptimisedServer whose plan execution slows down by the network
    platform's ``time_scale`` (sleep proportional to the excess), so
    observed per-image latency rises exactly like on a slower machine."""
    from repro.service import OptimisedServer

    class DriftingServer(OptimisedServer):
        def _run_plan(self, o, xs, weights):
            out = super()._run_plan(o, xs, weights)
            scale = getattr(o.platform, "time_scale", 1.0) or 1.0
            if scale != 1.0:
                time.sleep(0.02 * xs.shape[0] * (scale - 1.0))
            return out

    return DriftingServer(**kw)


def recalibration_pass(opt, *, sample_n: int, charge_s: float = 0.05,
                       timeout_s: float = 120.0) -> Dict:
    """Drift → detect → recalibrate-from-served-traffic → hot_swap, timed
    against the fresh-profiling alternative on the same drifted platform."""
    from repro.service import make_recalibrator, reoptimise

    platform = _charged_platform(opt.platform.name, charge_s,
                                 opt.platform.max_triplets)
    opt = dataclasses.replace(opt, platform=platform)

    timing: Dict = {}
    inner = make_recalibrator(sample_n=sample_n, mode="factor")

    def recalibrate(o, served=None):
        p0 = platform.profiled_configs
        t0 = time.perf_counter()
        new = inner(o, served=served)
        timing["served_seconds"] = time.perf_counter() - t0
        timing["served_profiled_configs"] = platform.profiled_configs - p0
        return new

    server = _drifting_server(
        max_batch=4, latency_budget_ms=1e9, workers=2, max_wait_ms=3.0,
        drift_threshold=1.5, drift_alpha=0.5, drift_calib_obs=2,
        recalibrate=recalibrate)
    server.register(opt)
    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((4, n0.c, n0.im, n0.im)).astype(np.float32)

    # healthy phase: until the reference and the observation buffer exist
    deadline = time.time() + timeout_s
    while (server.stats(opt.net)["observed_dispatches"] < 6
           and time.time() < deadline):
        server.serve(opt.net, xs)
    platform.time_scale = 4.0          # the machine gets 4x slower
    platform.invalidate_datasets()
    while (server.stats(opt.net)["recalibrations"] == 0
           and time.time() < deadline):
        server.serve(opt.net, xs)
    st = server.stats(opt.net)
    server.stop()

    # the alternative on the same drifted platform: freshly profile the
    # full calibration sample (pre-§8.5 behaviour), then recalibrate
    p0 = platform.profiled_configs
    t0 = time.perf_counter()
    sample = platform.measure_sample(sample_n, seed=999)
    reoptimise(opt, sample=sample, mode="factor")
    fresh_seconds = time.perf_counter() - t0
    return {"recalibrations": st["recalibrations"],
            "generation": st["generation"],
            "sample": st["recal_sample"],
            "served_seconds": timing.get("served_seconds"),
            "served_profiled_configs": timing.get("served_profiled_configs"),
            "fresh_seconds": fresh_seconds,
            "fresh_profiled_configs": platform.profiled_configs - p0,
            "charge_s_per_config": charge_s,
            "drift_ratio_at_stop": st["drift_ratio"]}


def fleet_recal_pass(*, net: str, platform: str, max_triplets: int,
                     max_iters: int, charge_s: float = 0.05,
                     timeout_s: float = 120.0) -> Dict:
    """Fleet calibration sharing (DESIGN.md §14): two hosts of the same
    hardware class share one simulated object-store bucket. Host A
    optimises cold against it, serves a drifting machine, recalibrates from
    its own served traffic, and publishes the evidence under the platform's
    pool fingerprint. Host B warm-starts everything from the shared bucket,
    never serves a request, polls the pool, and hot-swaps a recalibration
    built from A's published evidence alone. Both hosts calibrate on the
    evidence's config coverage (a fresh top-up would defeat the
    zero-profiling objective), so the gate can require ZERO freshly
    profiled configs for B — and byte-identical post-swap assignments."""
    from repro.service import (ArtifactStore, ObjectStoreBackend,
                               layer_profile, make_recalibrator, optimise)

    shared = ObjectStoreBackend()
    storeA = ArtifactStore(backend=shared.share())
    storeB = ArtifactStore(backend=shared.share())

    platformA = _charged_platform(platform, charge_s, max_triplets)
    t0 = time.perf_counter()
    optA = optimise(net, platformA, store=storeA, executable=True,
                    max_iters=max_iters)
    a_cold_seconds = time.perf_counter() - t0
    prof = layer_profile(optA)
    n_cfg = len({tuple(map(int, r)) for r in prof.feats})

    serverA = _drifting_server(
        max_batch=4, latency_budget_ms=1e9, workers=2, max_wait_ms=3.0,
        drift_threshold=1.5, drift_alpha=0.5, drift_calib_obs=2,
        recalibrate=make_recalibrator(store=storeA, sample_n=n_cfg,
                                      mode="factor", pool=True, host="A"))
    serverA.register(optA)
    n0 = optA.spec.nodes[0]
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((4, n0.c, n0.im, n0.im)).astype(np.float32)
    deadline = time.time() + timeout_s
    while (serverA.stats(optA.net)["observed_dispatches"] < 6
           and time.time() < deadline):
        serverA.serve(optA.net, xs)
    platformA.time_scale = 4.0
    platformA.invalidate_datasets()
    while (serverA.stats(optA.net)["recalibrations"] == 0
           and time.time() < deadline):
        serverA.serve(optA.net, xs)
    stA = serverA.stats(optA.net)
    with serverA._cond:
        a_new = serverA._nets[optA.net].opt
    serverA.stop()
    published = storeA.drift_entries(platformA.pool_fingerprint())

    # host B: same hardware class, fresh process — everything warm-loads
    platformB = _charged_platform(platform, charge_s, max_triplets)
    t0 = time.perf_counter()
    optB = optimise(net, platformB, store=storeB, executable=True,
                    max_iters=max_iters)
    b_warm_seconds = time.perf_counter() - t0

    serverB = _drifting_server(
        max_batch=4, latency_budget_ms=1e9, workers=2, max_wait_ms=3.0,
        drift_threshold=1.5, drift_alpha=0.5, drift_calib_obs=2,
        recalibrate=make_recalibrator(store=storeB, sample_n=n_cfg,
                                      mode="factor", pool=True, host="B"))
    serverB.register(optB)
    p0 = platformB.profiled_configs
    t0 = time.perf_counter()
    polled = serverB.poll_pool(storeB, host="B")
    deadline = time.time() + timeout_s
    while not serverB.recalibrations_idle() and time.time() < deadline:
        time.sleep(0.01)
    b_recal_seconds = time.perf_counter() - t0
    stB = serverB.stats(optB.net)
    with serverB._cond:
        b_new = serverB._nets[optB.net].opt
    serverB.stop()

    return {"sample_n": n_cfg,
            "a_cold_seconds": a_cold_seconds,
            "a_recalibrations": stA["recalibrations"],
            "a_generation": stA["generation"],
            "published_entries": len(published),
            "b_warm": optB.warm,
            "b_warm_seconds": b_warm_seconds,
            "b_polled": polled,
            "b_recalibrations": stB["recalibrations"],
            "b_recal_seconds": b_recal_seconds,
            "b_recal_error": stB["last_recal_error"],
            "b_profiled_configs": platformB.profiled_configs - p0,
            "b_sample": stB["recal_sample"],
            "warm_assignments_match": optB.assignment == optA.assignment,
            "assignments_match": b_new.assignment == a_new.assignment}


def deadline_pass(opt, requests: int, budget_ms: float,
                  max_wait_ms: float = 200.0) -> Dict:
    """Paced lone-request load twice: unbounded budget (windows run to the
    static cap) vs a tight budget (deadline-capped, monitor-shrunk). The
    gate: with the budget set, steady-state p99 queueing stays within it."""
    from repro.primitives.executor import make_weights
    from repro.service import OptimisedServer

    weights = make_weights(opt.spec)

    def run(budget) -> Dict:
        server = OptimisedServer(max_batch=16, latency_budget_ms=budget,
                                 workers=2, max_wait_ms=max_wait_ms,
                                 queue_depth=4096)
        server.register(opt, weights=weights)
        n0 = opt.spec.nodes[0]
        rng = np.random.default_rng(4)
        imgs = rng.standard_normal(
            (8, n0.c, n0.im, n0.im)).astype(np.float32)
        server.serve(opt.net, imgs[:2])            # warm small buckets
        tickets = []
        for i in range(requests):                  # paced lone arrivals:
            tickets.append(server.submit(opt.net, imgs[i % len(imgs)]))
            time.sleep(0.02)                       # windows, not batch-fill,
        for t in tickets:                          # decide dispatch
            t.wait(60.0)
        st = server.stats(opt.net)
        server.stop()
        waits = np.array([t.queue_wait_s for t in tickets
                          if t.done and not t.rejected], np.float64)
        steady = waits[len(waits) // 2:]           # after window adaptation
        return {"budget_ms": budget, "requests": len(tickets),
                "queue_wait_p50_ms": float(np.percentile(waits, 50)) * 1e3,
                "queue_wait_p99_ms": float(np.percentile(waits, 99)) * 1e3,
                "steady_p99_ms": float(np.percentile(steady, 99)) * 1e3,
                "budget_hit_rate": (float(np.mean(waits <= budget * 1e-3))
                                    if np.isfinite(budget) else 1.0),
                "window_scale": st["window_scale"],
                "effective_wait_ms": st["effective_wait_ms"],
                "dispatches": st["dispatches"]}

    return {"max_wait_ms": max_wait_ms,
            "unbounded": run(1e9), "budgeted": run(budget_ms)}


def availability_pass(opt, *, budget_ms: float, workers: int = 2) -> Dict:
    """Fault-tolerant serving under a seeded chaos plan (DESIGN.md §11):
    backend a of a two-backend route is poisoned — three dispatches raise
    (retry included), the first half-open probe hangs past the execution
    deadline, the next stalls past it, the third is clean — while b stays
    healthy. A closed-loop client drives bursts until the breaker has
    tripped and recovered, then a little clean traffic. The row reports the
    availability contract the chaos soak test asserts: accepted vs served,
    degraded (fallback) count, zero lost, zero duplicated (exact accounting
    identity), breaker open/close counts, worker restarts."""
    from repro.primitives.executor import make_weights
    from repro.primitives.plan import heuristic_assignment
    from repro.service import (Fault, FaultInjector, OptimisedNetwork,
                               OptimisedServer)

    spec = opt.spec
    weights = make_weights(spec)
    n0 = spec.nodes[0]
    rng = np.random.default_rng(6)
    imgs = rng.standard_normal((4, n0.c, n0.im, n0.im)).astype(np.float32)
    net = "avail_cnn"

    def mk(pred):
        return OptimisedNetwork.from_assignment(
            spec, heuristic_assignment(spec), net=net, predicted_cost_s=pred)

    # warm the global plan cache so healthy dispatches never pay jit compile
    # against the execution deadline
    warm = OptimisedServer(max_batch=4, latency_budget_ms=budget_ms)
    warm.register(mk(1e-3), weights=weights)
    for b in (1, 2, 4):
        warm.serve(net, imgs[:b])

    inj = FaultInjector([
        Fault("raise", net=f"{net}#a", first=0, last=6),
        Fault("hang", net=f"{net}#a", first=6, last=7, seconds=0.75),
        Fault("slowdown", net=f"{net}#a", first=7, last=8, seconds=0.3)])
    server = OptimisedServer(
        max_batch=4, latency_budget_ms=budget_ms, workers=workers,
        max_wait_ms=0.0, queue_depth=10_000, exec_deadline_ms=60.0,
        breaker_failures=3, breaker_cooldown_ms=120.0, faults=inj)
    # a predicts far cheaper: preferred whenever its breaker allows, so the
    # fault schedule is hit deterministically; b is the healthy spill target
    server.register(mk(1e-6), weights=weights, backend="a")
    server.register(mk(1e-3), weights=weights, backend="b")

    tickets = []
    recovered = False
    t0 = time.perf_counter()
    deadline = t0 + 90.0
    while time.perf_counter() < deadline:
        burst = [server.submit(net, imgs[len(tickets) % 4])
                 for _ in range(2)]
        tickets.extend(burst)
        for t in burst:
            t.wait(30.0)
        br = server.stats(net)["backends"]["a"]["breaker"]
        if br["closes"] >= 1 and br["state"] == "closed":
            recovered = True
            break
        time.sleep(0.01)
    for _ in range(5):                         # post-recovery clean traffic
        burst = [server.submit(net, imgs[len(tickets) % 4])
                 for _ in range(2)]
        tickets.extend(burst)
        for t in burst:
            t.wait(30.0)
    dt = time.perf_counter() - t0
    s = server.stats(net)
    restarts = server._pool.restarts if server._pool is not None else 0
    server.stop(timeout=60.0)

    accepted = sum(1 for t in tickets if not t.rejected)
    lost = sum(1 for t in tickets if not t.done)
    served = [t for t in tickets if t.done and t.result is not None]
    ba = s["backends"]["a"]["breaker"]
    return {"tickets": len(tickets), "accepted": accepted, "lost": lost,
            "served": len(served),
            "degraded": sum(1 for t in served if t.degraded),
            "failed_tickets": s["failed_tickets"],
            "availability": len(served) / max(accepted, 1),
            # != 0 would mean a ticket was double-delivered or lost between
            # the primary path and the fallback: the accounting identity
            "duplicated": (s["images"] + s["fallback_images"]) - len(served),
            "seconds": dt,
            "injected_faults": [list(e) for e in inj.injected],
            "breaker_opens": ba["opens"], "breaker_closes": ba["closes"],
            "breaker_state": ba["state"], "breaker_recovered": recovered,
            "worker_restarts": restarts, "rollbacks": s["rollbacks"],
            "spill_images": s["backends"]["b"]["images"],
            "failure_ledger": s["failures"]}


def frontend_scaling_pass(opt, requests: int, budget_ms: float, *,
                          workers: int, procs: int,
                          chaos_requests: int = 48) -> Dict:
    """Thread front end vs the multi-process shared-memory front end
    (DESIGN.md §12) on the same warmed single-net load, equal workers.

    Thread pass: ``procs`` submitter threads push lone requests through
    ``submit`` — batch assembly (payload copy, pow2 pad, result slicing)
    runs under the parent's GIL. Process pass: the same request count
    through ``ProcessFrontend.drive`` — intake processes write payloads
    once into shared-memory slabs and the workers execute zero-copy views.
    A second drive runs under an injected fault plan (the shm chaos soak):
    the accounting identity — served + failed + rejected == requests, with
    zero lost and zero duplicated — must survive the slab path."""
    import threading

    from repro.primitives.executor import make_weights
    from repro.service import Fault, FaultInjector, OptimisedServer

    spec = opt.spec
    weights = make_weights(spec)
    n0 = spec.nodes[0]
    rng = np.random.default_rng(8)

    def mk_server(**kw):
        server = OptimisedServer(max_batch=8, latency_budget_ms=budget_ms,
                                 workers=workers, max_wait_ms=2.0,
                                 queue_depth=4096, **kw)
        server.register(opt, weights=weights)
        for b in (1, 2, 4, 8):        # warm every (net, bucket) plan
            server.serve(opt.net, rng.standard_normal(
                (b, n0.c, n0.im, n0.im)).astype(np.float32))
        return server

    # -- thread front end --------------------------------------------------
    server = mk_server()
    xs = rng.standard_normal(
        (requests, n0.c, n0.im, n0.im)).astype(np.float32)
    shares = np.array_split(np.arange(requests), procs)
    tickets: list = [[] for _ in shares]

    def submitter(i):
        for j in shares[i]:
            tickets[i].append(server.submit(opt.net, xs[j]))

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(procs)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    flat = [t for part in tickets for t in part]
    for t in flat:
        t.wait(300.0)
    dt = time.perf_counter() - t0
    thread_row = {"images_per_s": requests / dt, "seconds": dt,
                  "failed": sum(1 for t in flat if not t.done or t.error)}
    server.stop()

    # -- process front end (clean, then under the chaos fault plan) --------
    server = mk_server(frontend_procs=procs)
    fe = server.frontend()
    clean = fe.drive(opt.net, requests, seed=9)
    server.stop()

    inj = FaultInjector([Fault("raise", net=opt.net, first=5, last=7)])
    server = mk_server(frontend_procs=procs, faults=inj)
    s0 = server.stats(opt.net)                 # warm traffic, pre-drive
    chaos = server.frontend().drive(opt.net, chaos_requests, seed=10)
    s = server.stats(opt.net)
    # lost/duplicated on the slab path: every request resolved exactly once,
    # and the served-image accounting delta matches the deliveries
    chaos["lost"] = chaos_requests - (chaos["served"] + chaos["failed"]
                                      + chaos["rejected"])
    chaos["duplicated"] = ((s["images"] + s["fallback_images"])
                           - (s0["images"] + s0["fallback_images"])
                           - chaos["served"])
    chaos["injected_faults"] = len(inj.injected)
    server.stop()

    return {"workers": workers, "procs": procs, "requests": requests,
            "threads": thread_row, "processes": clean,
            "speedup": clean["images_per_s"] / thread_row["images_per_s"],
            "chaos": chaos}


def bucket_cost_pass(nets, *, buckets=(1, 2, 4), rounds: int = 24) -> Dict:
    """Batch-shape-aware vs linear per-image cost on really-served traffic
    (DESIGN.md §12.3), per zoo net.

    Each net serves ``rounds`` bursts per pow2 bucket (pump mode, plans
    warmed) with per-dispatch per-image latency recorded; even rounds fit,
    odd rounds evaluate. The linear model is the count-weighted mean
    per-image cost over the fit half (what a batch-size-invariant predictor
    settles on); the bucket model is ``BucketScaleHead`` fitted from the
    same half. Error is the count-weighted mean absolute log-space gap
    between each bucket's held-out **median** and the model — the median
    (plus the larger round count) keeps a single scheduler stall on a
    loaded runner from deciding the gate, which matters more now that the
    §13.3 dispatch fast path has removed most of the fixed per-dispatch
    overhead the head models. The gate requires the bucket model strictly
    below linear on every listed net."""
    from repro.core.perfmodel import BucketScaleHead
    from repro.models import cnn_zoo
    from repro.primitives.plan import heuristic_assignment
    from repro.service import OptimisedNetwork, OptimisedServer

    out = {}
    for net in nets:
        spec = cnn_zoo.get(net)
        opt = OptimisedNetwork.from_assignment(
            spec, heuristic_assignment(spec), predicted_cost_s=2e-3)
        server = OptimisedServer(max_batch=8, latency_budget_ms=1e9)
        server.register(opt)
        n0 = spec.nodes[0]
        rng = np.random.default_rng(7)
        xs = {b: rng.standard_normal(
            (b, n0.c, n0.im, n0.im)).astype(np.float32) for b in buckets}
        for b in buckets:                      # warm: jit compile excluded
            server.serve(net, xs[b])
        fit, ev = [], {b: [] for b in buckets}
        for r in range(rounds):
            for b in buckets:
                t0 = time.perf_counter()
                server.serve(net, xs[b])
                per = (time.perf_counter() - t0) / b
                if r % 2 == 0:
                    fit.append((b, np.log(per)))
                else:
                    ev[b].append(np.log(per))
        server.stop()
        head = BucketScaleHead.fit(fit, normalize=False)
        counts: Dict[int, int] = {}
        for b, _ in fit:
            counts[b] = counts.get(b, 0) + 1
        base = float(np.average(
            [np.log(head.scale(b)) for b in head.buckets()],
            weights=[counts[b] for b in head.buckets()]))
        lin, buc, w = [], [], []
        for b in buckets:
            m = float(np.median(ev[b]))
            lin.append(abs(m - base))
            buc.append(abs(m - np.log(head.scale(b))))
            w.append(len(ev[b]))
        out[net] = {
            "per_image_ms": {int(b): float(np.exp(np.log(head.scale(b))))
                             * 1e3 for b in head.buckets()},
            "linear_per_image_ms": float(np.exp(base)) * 1e3,
            "linear_err": float(np.average(lin, weights=w)),
            "bucket_err": float(np.average(buc, weights=w)),
        }
        out[net]["bucket_wins"] = (out[net]["bucket_err"]
                                   < out[net]["linear_err"])
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools / fewer iters (CI gate)")
    ap.add_argument("--net", default="edge_cnn")
    ap.add_argument("--platform", default="arm")
    ap.add_argument("--base", default="intel")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--budget-ms", type=float, default=50.0)
    ap.add_argument("--workers", type=int, default=3,
                    help="worker threads for the concurrent serving row")
    ap.add_argument("--max-wait-ms", type=float, default=4.0,
                    help="batch window for the concurrent serving row")
    ap.add_argument("--recal-sample-n", type=int, default=12,
                    help="calibration sample size for the drift "
                         "recalibration row")
    ap.add_argument("--backends", default="arm,amd",
                    help="comma-separated platform specs for the "
                         "cross-backend routing row (simulated-CPU "
                         "platforms: the row's device-charge model needs "
                         "real per-image compute to be incidental, and "
                         "since tile variants lower to real interpret-mode "
                         "Pallas kernels (DESIGN.md §13.1) a 'tpu' backend "
                         "burns enough host CPU to fight the other "
                         "backend for cores instead of overlapping)")
    ap.add_argument("--frontend-procs", type=int, default=2,
                    help="intake processes for the frontend scaling row")
    ap.add_argument("--bucket-nets", default="edge_cnn,alexnet",
                    help="comma-separated zoo nets for the bucket-aware "
                         "cost model row (>= 2)")
    ap.add_argument("--store", default=None,
                    help="artifact store root (default: fresh temp dir, "
                         "removed afterwards, so the first pass is cold)")
    args = ap.parse_args()

    max_triplets = 30 if args.smoke else 60
    max_iters = 600 if args.smoke else 2000
    requests = args.requests or (32 if args.smoke else 128)

    root = args.store or tempfile.mkdtemp(prefix="repro-service-e2e-")
    cleanup = args.store is None
    try:
        kw = dict(net=args.net, platform=args.platform, base=args.base,
                  max_triplets=max_triplets, max_iters=max_iters)
        cold = optimise_pass(root, **kw)
        warm = optimise_pass(root, **kw)
        ratio = cold["seconds"] / max(warm["seconds"], 1e-9)
        same = cold["opt"].assignment == warm["opt"].assignment
        emit("service.optimise_cold_us", cold["seconds"] * 1e6,
             f"{cold['seconds']:.2f}s train+select")
        emit("service.optimise_warm_us", warm["seconds"] * 1e6,
             f"{warm['seconds']:.3f}s from artifacts ({ratio:.0f}x)")

        served = serve_pass(warm["opt"], requests, args.budget_ms)
        emit("service.served_img_s", 1e6 / served["images_per_s"],
             f"{served['images_per_s']:.1f} img/s "
             f"cap={served['batch_cap']} dispatches={served['dispatches']}")

        rpn = max(requests // 2, 16)
        concurrent = concurrent_pass(warm["opt"], rpn, args.budget_ms,
                                     args.workers, args.max_wait_ms)
        emit("service.concurrent_img_s",
             1e6 / concurrent["concurrent"]["images_per_s"],
             f"{concurrent['concurrent']['images_per_s']:.1f} img/s over "
             f"{len(concurrent['networks'])} nets with "
             f"{args.workers} workers ({concurrent['speedup']:.2f}x serial, "
             f"queue p50/p99 "
             f"{concurrent['concurrent']['queue_wait_p50_ms']:.2f}/"
             f"{concurrent['concurrent']['queue_wait_p99_ms']:.2f} ms)")

        recal = recalibration_pass(warm["opt"], sample_n=args.recal_sample_n)
        frac = (recal["sample"] or {}).get("served_fraction", 0.0)
        if recal["served_seconds"] is not None:
            served_note = (f"{recal['served_seconds']:.2f}s, "
                           f"{frac:.0%} served rows, "
                           f"{recal['served_profiled_configs']} configs "
                           f"profiled")
        else:                          # drift loop never hot-swapped: the
            served_note = "NO served-sample recalibration ran"   # gate fails
        emit("service.recal_served_us",
             (recal["served_seconds"] or float("inf")) * 1e6,
             f"drift recal from served traffic: {served_note} "
             f"(fresh path: {recal['fresh_seconds']:.2f}s for "
             f"{recal['fresh_profiled_configs']} configs)")

        fr = fleet_recal_pass(net=args.net, platform=args.platform,
                              max_triplets=max_triplets, max_iters=max_iters)
        emit("service.fleet_recal_us", fr["b_recal_seconds"] * 1e6,
             f"host B pooled recal {fr['b_recal_seconds']:.2f}s from "
             f"{fr['published_entries']} published entr"
             f"{'y' if fr['published_entries'] == 1 else 'ies'}, "
             f"{fr['b_profiled_configs']} configs profiled "
             f"(warm-start {'ok' if fr['b_warm'] else 'COLD'} "
             f"{fr['b_warm_seconds']:.2f}s, assignments "
             f"{'match' if fr['assignments_match'] else 'DIVERGE'})")

        mb = multibackend_pass(root, net=args.net,
                               backends=tuple(args.backends.split(",")),
                               base=args.base, max_triplets=max_triplets,
                               max_iters=max_iters, requests=requests,
                               budget_ms=args.budget_ms,
                               workers=max(args.workers, 2))
        emit("service.multibackend_img_s",
             1e6 / mb["routed"]["images_per_s"],
             f"{mb['routed']['images_per_s']:.1f} img/s routed across "
             f"{len(mb['backends'])} backends "
             f"({mb['routed_vs_best_single']:.2f}x best single "
             f"'{mb['best_single']}' "
             f"{mb['single'][mb['best_single']]['images_per_s']:.1f} img/s, "
             f"repro={'ok' if mb['reproducible_from_store'] else 'MISMATCH'})")

        deadline = deadline_pass(warm["opt"], max(rpn, 96), args.budget_ms)
        emit("service.deadline_p99_us",
             deadline["budgeted"]["steady_p99_ms"] * 1e3,
             f"deadline windows: steady p99 "
             f"{deadline['budgeted']['steady_p99_ms']:.1f} ms vs "
             f"{args.budget_ms:.0f} ms budget "
             f"(hit rate {deadline['budgeted']['budget_hit_rate']:.0%}, "
             f"window scale {deadline['budgeted']['window_scale']:.2f}; "
             f"unbounded p99 "
             f"{deadline['unbounded']['queue_wait_p99_ms']:.1f} ms)")

        fe = frontend_scaling_pass(warm["opt"], max(requests, 128),
                                   args.budget_ms,
                                   workers=max(args.workers, 2),
                                   procs=args.frontend_procs)
        emit("service.frontend_img_s",
             1e6 / fe["processes"]["images_per_s"],
             f"{fe['processes']['images_per_s']:.1f} img/s through "
             f"{fe['procs']} shm intake processes "
             f"({fe['speedup']:.2f}x the {fe['procs']}-thread front end "
             f"{fe['threads']['images_per_s']:.1f} img/s; chaos soak "
             f"{fe['chaos']['served']}/{fe['chaos']['requests']} served, "
             f"{fe['chaos']['lost']} lost, "
             f"{fe['chaos']['duplicated']:+d} dup)")

        bucket = bucket_cost_pass(tuple(args.bucket_nets.split(",")))
        worst = max(bucket, key=lambda n: bucket[n]["bucket_err"]
                    / max(bucket[n]["linear_err"], 1e-12))
        emit("service.bucket_cost_err_mlog",
             bucket[worst]["bucket_err"] * 1e3,
             "bucket-aware vs linear per-image cost (log-space err): " +
             ", ".join(f"{n} {r['bucket_err']:.3f} vs {r['linear_err']:.3f}"
                       for n, r in bucket.items()))

        avail = availability_pass(warm["opt"], budget_ms=args.budget_ms,
                                  workers=max(args.workers, 2))
        emit("service.unavailability_ppm",
             (1.0 - avail["availability"]) * 1e6,
             f"{avail['availability']:.2%} of {avail['accepted']} tickets "
             f"served under injected faults ({avail['degraded']} degraded, "
             f"{avail['lost']} lost, {avail['duplicated']:+d} dup, "
             f"breaker opens/closes "
             f"{avail['breaker_opens']}/{avail['breaker_closes']}, "
             f"{avail['worker_restarts']} workers replaced)")

        results = {
            "mode": "smoke" if args.smoke else "full",
            "net": args.net, "platform": args.platform, "base": args.base,
            "cold_seconds": cold["seconds"],
            "warm_seconds": warm["seconds"],
            "warm_speedup": ratio,
            "warm_was_warm": warm["warm"],
            "same_assignment": same,
            "assignment": {str(k): v for k, v in
                           sorted(warm["opt"].assignment.items())},
            "served": served,
            "concurrent_serving": concurrent,
            "recalibration": recal,
            "fleet_recalibration": fr,
            "multibackend": mb,
            "deadline_batching": deadline,
            "frontend_scaling": fe,
            "bucket_cost": bucket,
            "availability": avail,
        }
        with open(OUT_PATH, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"wrote {OUT_PATH} (warm optimise {ratio:.0f}x faster than cold)")

        failures = []
        if ratio < 10.0:
            failures.append(f"warm-start only {ratio:.1f}x faster (< 10x)")
        if not same:
            failures.append("warm-start selected a different assignment")
        if not warm["warm"]:
            failures.append("second pass retrained instead of warm-loading")
        # the worker pool's overlap win needs parallel hardware; on a
        # one-core runner the honest expectation is parity with the serial
        # pump, so only gate strictly when there are cores to overlap on
        min_conc = 1.0 if (os.cpu_count() or 1) > 1 else 0.85
        if concurrent["speedup"] < min_conc:
            failures.append(f"concurrent multi-network throughput only "
                            f"{concurrent['speedup']:.2f}x the serial pump "
                            f"(< {min_conc:.2f}x on {os.cpu_count()} cpu)")
        if concurrent["concurrent"]["failed"] or concurrent["serial"]["failed"]:
            failures.append("concurrent serving failed requests")
        if recal["recalibrations"] < 1:
            failures.append("drift recalibration did not hot-swap")
        if frac < 0.5:
            failures.append(f"recalibration used only {frac:.0%} served "
                            f"observations (< 50%)")
        if not (recal["served_seconds"] is not None
                and recal["served_seconds"] < recal["fresh_seconds"]):
            failures.append(
                f"served-sample recalibration ({recal['served_seconds']}s) "
                f"not faster than fresh profiling "
                f"({recal['fresh_seconds']:.2f}s)")
        if fr["a_recalibrations"] < 1:
            failures.append("fleet: host A never hot-swapped from served "
                            "drift")
        if fr["published_entries"] < 1:
            failures.append("fleet: host A published no drift evidence")
        if not fr["b_warm"]:
            failures.append("fleet: host B did not warm-start from the "
                            "shared backend")
        if not fr["warm_assignments_match"]:
            failures.append("fleet: host B warm-started a different "
                            "assignment than host A")
        if fr["b_polled"] != 1 or fr["b_recalibrations"] != 1:
            failures.append(
                f"fleet: host B polled {fr['b_polled']} / hot-swapped "
                f"{fr['b_recalibrations']} from pooled evidence "
                f"(expected 1/1, error: {fr['b_recal_error']})")
        if fr["b_profiled_configs"] != 0:
            failures.append(f"fleet: host B freshly profiled "
                            f"{fr['b_profiled_configs']} configs "
                            f"(expected 0)")
        if (fr["b_sample"] or {}).get("fresh_rows") != 0:
            failures.append("fleet: host B's recalibration sample was not "
                            "pure pooled evidence")
        if (fr["b_sample"] or {}).get("pooled_sources", 0) < 1:
            failures.append("fleet: host B's recalibration pulled no "
                            "pooled datasets")
        if not fr["assignments_match"]:
            failures.append("fleet: pooled recalibration selected a "
                            "different assignment than host A's")
        if mb["routed_vs_best_single"] < 1.0:
            failures.append(
                f"cross-backend routing only {mb['routed_vs_best_single']:.2f}x "
                f"the best single backend ('{mb['best_single']}')")
        if mb["routed"]["failed"] or any(s["failed"]
                                         for s in mb["single"].values()):
            failures.append("multi-backend serving failed requests")
        if not mb["reproducible_from_store"]:
            failures.append("per-backend assignments not reproducible from "
                            "the warm artifact store")
        if deadline["budgeted"]["steady_p99_ms"] > args.budget_ms:
            failures.append(
                f"deadline windows: steady p99 queueing "
                f"{deadline['budgeted']['steady_p99_ms']:.1f} ms exceeds the "
                f"{args.budget_ms:.0f} ms budget")
        # like the concurrency gate: the process front end's win is freeing
        # the parent GIL for more hardware — on a <=2-core runner there is
        # none spare, so the honest expectation is parity with noise
        min_fe = 1.0 if (os.cpu_count() or 1) > 2 else 0.75
        if fe["speedup"] < min_fe:
            failures.append(f"process front end only {fe['speedup']:.2f}x "
                            f"the thread front end "
                            f"(< {min_fe:.2f}x on {os.cpu_count()} cpu)")
        if fe["threads"]["failed"] or fe["processes"]["failed"]:
            failures.append("front-end scaling row failed requests")
        if fe["chaos"]["lost"]:
            failures.append(f"{fe['chaos']['lost']} ticket(s) lost on the "
                            f"shm front end under faults")
        if fe["chaos"]["duplicated"]:
            failures.append(f"shm front end accounting off by "
                            f"{fe['chaos']['duplicated']} under faults")
        if fe["chaos"]["served"] / fe["chaos"]["requests"] < 0.99:
            failures.append(f"shm front end served only "
                            f"{fe['chaos']['served']} of "
                            f"{fe['chaos']['requests']} under faults")
        not_winning = [n for n, r in bucket.items() if not r["bucket_wins"]]
        if len(bucket) < 2 or not_winning:
            failures.append(
                f"bucket-aware cost model not strictly better than linear "
                f"on every net ({', '.join(not_winning) or 'too few nets'})")
        if avail["availability"] < 0.99:
            failures.append(f"availability {avail['availability']:.2%} under "
                            f"injected faults (< 99%)")
        if avail["lost"]:
            failures.append(f"{avail['lost']} ticket(s) lost under faults")
        if avail["duplicated"]:
            failures.append(f"ticket accounting off by {avail['duplicated']} "
                            f"(duplicated or mis-counted delivery)")
        if not avail["breaker_recovered"]:
            failures.append("poisoned backend's breaker never recovered "
                            "through a half-open probe")
        if failures:
            print("FAIL: " + "; ".join(failures), file=sys.stderr)
            return 1
        return 0
    finally:
        if cleanup:
            shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
