"""Service-layer end to end: cold vs warm optimise time, and served img/s.

Cold pass: a fresh artifact store — pretrain the base platform model,
calibrate onto the target platform, PBQP-select. Warm pass: identical calls
against the now-populated store — every model and the selection must come
back from disk, selecting the *same assignment*, ≥10x faster (the paper's
Table 4 "seconds, not hours" claim as a regression gate). Then the optimised
network is served through ``OptimisedServer`` for a throughput figure.

Writes ``BENCH_service.json``. Exits nonzero if the warm pass is < 10x
faster than cold or picks a different assignment — the CI smoke gate
(``--smoke``).

Run:  PYTHONPATH=src:. python benchmarks/service_e2e.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from benchmarks.common import emit

OUT_PATH = os.environ.get("REPRO_BENCH_SERVICE_JSON", "BENCH_service.json")


def optimise_pass(store_root: str, *, net: str, platform: str, base: str,
                  max_triplets: int, max_iters: int) -> Dict:
    """One full optimise run against ``store_root``; fresh Platform objects
    so nothing is warm except what the store provides."""
    from repro.service import ArtifactStore, get_platform, optimise

    store = ArtifactStore(store_root)
    t0 = time.perf_counter()
    base_models = get_platform(base, max_triplets=max_triplets).pretrain(
        "nn2", store=store, max_iters=max_iters)
    opt = optimise(net, get_platform(platform, max_triplets=max_triplets),
                   store=store, base=base_models, mode="factor",
                   executable=True)
    seconds = time.perf_counter() - t0
    return {"seconds": seconds, "opt": opt,
            "warm": base_models.warm and opt.warm}


def serve_pass(opt, requests: int, budget_ms: float) -> Dict:
    from repro.service import OptimisedServer

    server = OptimisedServer(latency_budget_ms=budget_ms)
    server.register(opt)
    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((requests, n0.c, n0.im, n0.im)).astype(np.float32)
    server.serve(opt.net, xs)                          # warm the plan cache
    s0 = server.stats(opt.net)
    t0 = time.perf_counter()
    server.serve(opt.net, xs)
    dt = time.perf_counter() - t0
    s = server.stats(opt.net)                          # delta = timed pass only
    return {"requests": requests, "seconds": dt,
            "images_per_s": requests / dt, "batch_cap": s["batch_cap"],
            "dispatches": s["dispatches"] - s0["dispatches"],
            "padded": s["padded"] - s0["padded"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools / fewer iters (CI gate)")
    ap.add_argument("--net", default="edge_cnn")
    ap.add_argument("--platform", default="arm")
    ap.add_argument("--base", default="intel")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--budget-ms", type=float, default=50.0)
    ap.add_argument("--store", default=None,
                    help="artifact store root (default: fresh temp dir, "
                         "removed afterwards, so the first pass is cold)")
    args = ap.parse_args()

    max_triplets = 30 if args.smoke else 60
    max_iters = 600 if args.smoke else 2000
    requests = args.requests or (32 if args.smoke else 128)

    root = args.store or tempfile.mkdtemp(prefix="repro-service-e2e-")
    cleanup = args.store is None
    try:
        kw = dict(net=args.net, platform=args.platform, base=args.base,
                  max_triplets=max_triplets, max_iters=max_iters)
        cold = optimise_pass(root, **kw)
        warm = optimise_pass(root, **kw)
        ratio = cold["seconds"] / max(warm["seconds"], 1e-9)
        same = cold["opt"].assignment == warm["opt"].assignment
        emit("service.optimise_cold_us", cold["seconds"] * 1e6,
             f"{cold['seconds']:.2f}s train+select")
        emit("service.optimise_warm_us", warm["seconds"] * 1e6,
             f"{warm['seconds']:.3f}s from artifacts ({ratio:.0f}x)")

        served = serve_pass(warm["opt"], requests, args.budget_ms)
        emit("service.served_img_s", 1e6 / served["images_per_s"],
             f"{served['images_per_s']:.1f} img/s "
             f"cap={served['batch_cap']} dispatches={served['dispatches']}")

        results = {
            "mode": "smoke" if args.smoke else "full",
            "net": args.net, "platform": args.platform, "base": args.base,
            "cold_seconds": cold["seconds"],
            "warm_seconds": warm["seconds"],
            "warm_speedup": ratio,
            "warm_was_warm": warm["warm"],
            "same_assignment": same,
            "assignment": {str(k): v for k, v in
                           sorted(warm["opt"].assignment.items())},
            "served": served,
        }
        with open(OUT_PATH, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"wrote {OUT_PATH} (warm optimise {ratio:.0f}x faster than cold)")

        failures = []
        if ratio < 10.0:
            failures.append(f"warm-start only {ratio:.1f}x faster (< 10x)")
        if not same:
            failures.append("warm-start selected a different assignment")
        if not warm["warm"]:
            failures.append("second pass retrained instead of warm-loading")
        if failures:
            print("FAIL: " + "; ".join(failures), file=sys.stderr)
            return 1
        return 0
    finally:
        if cleanup:
            shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
