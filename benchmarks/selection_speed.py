"""Paper Table 4: time to optimise each CNN — performance-model inference
vs on-device profiling.

The model-inference time is measured for real (batched NN2 forward + PBQP).
The profiling cost is what the simulators say the measurements would take:
25 repeats of every applicable primitive on every layer (paper §4.1.1) plus
DLT profiling.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, trained_model
from repro.core.selection import ModelProvider, SimulatedProvider, select
from repro.models import cnn_zoo
from repro.primitives.conv import REGISTRY
from repro.profiler.simulators import PLATFORMS, dlt_time, primitive_time


def profiling_seconds(spec, platform: str, repeats: int = 25) -> float:
    plat = PLATFORMS[platform]
    total = 0.0
    for layer in spec.conv_layers:
        for p in REGISTRY.values():
            t = primitive_time(plat, p, *layer.config, noisy=False)
            if np.isfinite(t):
                total += t * repeats
    for (c, im) in {( l.k, l.out_im) for l in spec.conv_layers}:
        for s in ("chw", "hcw", "hwc"):
            for d in ("chw", "hcw", "hwc"):
                if s != d:
                    total += dlt_time(plat, s, d, c, im, noisy=False) * repeats
    return total


def main() -> dict:
    prim_m = trained_model("nn2", "intel")
    dlt_m = trained_model("nn2", "intel", role="dlt")
    provider = ModelProvider(prim_m, dlt_m)
    results = {}
    for net in cnn_zoo.PAPER_SELECTION_NETS:
        spec = cnn_zoo.get(net)
        t0 = time.perf_counter()
        res = select(spec, provider)
        model_ms = (time.perf_counter() - t0) * 1e3
        prof = {p: profiling_seconds(spec, p) for p in ("intel", "amd", "arm")}
        speedup = prof["arm"] / (model_ms / 1e3)
        results[net] = {"model_ms": model_ms, **{f"profile_{k}_s": v for k, v in prof.items()},
                        "speedup_vs_arm_profiling": speedup}
        # emit() takes microseconds per call; name the unit in the label so
        # the value and its label agree (model_ms is milliseconds).
        emit(f"table4.{net}.model_inference_us", model_ms * 1e3,
             f"model={model_ms:.3f}ms profiling intel={prof['intel']:.0f}s "
             f"amd={prof['amd']:.0f}s arm={prof['arm']:.0f}s "
             f"speedup={speedup:.0f}x optimal={res.optimal}")
    return results


if __name__ == "__main__":
    main()
