"""Paper Fig 8: the Intel model applied to AMD/ARM — directly and with the
1%-sample per-primitive factor correction — at both the estimation level
(MdRAE) and the GoogLeNet-selection level."""
from __future__ import annotations

from benchmarks.common import dataset, emit, trained_model
from repro.core.perfmodel import factor_correct
from repro.core.selection import (ModelProvider, SimulatedProvider, build_pbqp,
                                  network_cost, select)
from repro.models import cnn_zoo


def main() -> dict:
    results = {}
    intel = trained_model("nn2", "intel")
    intel_dlt = trained_model("nn2", "intel", role="dlt")
    spec = cnn_zoo.get("googlenet")
    for plat in ("amd", "arm"):
        ds = dataset(plat)
        tr, va, te = ds.split()
        native = trained_model("nn2", plat)
        sample = tr.subsample(0.01, seed=0)
        corrected = factor_correct(intel, sample.feats, sample.times)

        truth = SimulatedProvider(plat)
        g_truth = build_pbqp(spec, truth)        # one build, many evaluations
        c_opt = select(spec, truth).solver_cost
        dlt_native = trained_model("nn2", plat, role="dlt")
        for tag, model in (("intel", intel), ("factor_intel", corrected),
                           ("native", native)):
            md = model.mdrae(te.feats, te.times)
            prov = ModelProvider(model, dlt_native)
            c = network_cost(spec, select(spec, prov).assignment, graph=g_truth)
            inc = 100.0 * (c / c_opt - 1.0)
            results[f"{plat}.{tag}"] = {"mdrae": md, "increase_pct": inc}
            emit(f"fig8.{plat}.{tag}", md * 100,
                 f"mdrae={md*100:.1f}% googlenet_increase={inc:.2f}%")
    return results


if __name__ == "__main__":
    main()
