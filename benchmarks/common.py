"""Shared benchmark plumbing: cached Platform objects, artifact-store-backed
trained models, and CSV output.

One keying scheme (ROADMAP): benchmarks obtain trained models through the
platform verbs (``Platform.pretrain_prim`` / ``pretrain_dlt``), so a model
trained by a benchmark and the same model trained by ``Platform.pretrain``
share ONE content address in the artifact store — there is no benchmark-only
``tag`` field, and the FAST pool trimming happens once, at platform
construction, instead of per helper."""
from __future__ import annotations

import os
from typing import Dict, Optional

from repro.core.perfmodel import PerfModel
from repro.profiler.dataset import PerfDataset
from repro.service.artifacts import ArtifactStore
from repro.service.platforms import SimulatedPlatform

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")
FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

_store_state: list = []          # lazily built: [ArtifactStore] or [None]


def store() -> Optional[ArtifactStore]:
    """The benchmark artifact store, created on first use (importing this
    module must not create directories). None if the root is unwritable —
    benchmarks then run cache-less rather than crash."""
    if not _store_state:
        try:
            _store_state.append(ArtifactStore(ART))
        except OSError:
            _store_state.append(None)
    return _store_state[0]


_platforms: Dict[str, SimulatedPlatform] = {}


def platform(name: str) -> SimulatedPlatform:
    """One cached SimulatedPlatform per name. FAST trims the profiling pool
    here — platform construction — so every downstream dataset, model
    address, and provider agrees on the same pool."""
    if name not in _platforms:
        _platforms[name] = SimulatedPlatform(
            name, max_triplets=60 if FAST else None)
    return _platforms[name]


def dataset(name: str) -> PerfDataset:
    return platform(name).primitive_dataset()


def dlt_dataset(name: str) -> PerfDataset:
    return platform(name).dlt_dataset()


def trained_model(kind: str, plat: str, *, role: str = "prim",
                  max_iters: int = 8000, seed: int = 0,
                  cache: bool = True) -> PerfModel:
    """Natively trained performance model for ``plat``, through the platform
    verbs — stored at the same artifact address ``Platform.pretrain`` would
    use (warm-started across runs when the store is writable)."""
    iters = max_iters if not FAST else min(max_iters, 2000)
    st = store() if cache else None
    p = platform(plat)
    verb = p.pretrain_dlt if role == "dlt" else p.pretrain_prim
    model, _ = verb(kind, store=st, seed=seed, max_iters=iters)
    return model


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Contract from the scaffold: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.3f},{derived}")
