"""Shared benchmark plumbing: dataset + trained-model caches, CSV output."""
from __future__ import annotations

import os
import pickle
import time
from typing import Optional

import numpy as np

from repro.core.perfmodel import PerfModel, fit_perf_model
from repro.profiler.dataset import (PerfDataset, simulate_dlt_dataset,
                                    simulate_primitive_dataset)

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")
FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

_ds_cache = {}


def dataset(platform: str) -> PerfDataset:
    if ("prim", platform) not in _ds_cache:
        _ds_cache[("prim", platform)] = simulate_primitive_dataset(
            platform, max_triplets=60 if FAST else None)
    return _ds_cache[("prim", platform)]


def dlt_dataset(platform: str) -> PerfDataset:
    if ("dlt", platform) not in _ds_cache:
        _ds_cache[("dlt", platform)] = simulate_dlt_dataset(platform)
    return _ds_cache[("dlt", platform)]


def model_path(tag: str) -> str:
    d = os.path.join(ART, "models")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, tag + ".pkl")


def trained_model(tag: str, kind: str, ds: PerfDataset, *,
                  max_iters: int = 8000, seed: int = 0,
                  base: Optional[PerfModel] = None,
                  cache: bool = True) -> PerfModel:
    path = model_path(tag)
    if cache and base is None and os.path.exists(path):
        return PerfModel.load(path)
    tr, va, te = ds.split()
    m = fit_perf_model(kind, tr.feats, tr.times, va.feats, va.times,
                       columns=ds.columns, seed=seed, base=base,
                       max_iters=max_iters if not FAST else min(max_iters, 2000))
    if cache and base is None:
        try:
            m.save(path)
        except Exception:
            pass
    return m


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Contract from the scaffold: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.3f},{derived}")
