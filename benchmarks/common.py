"""Shared benchmark plumbing: dataset cache, artifact-store-backed trained
models (repro.service.artifacts — warm-start across runs, content-addressed
by platform/columns/dataset/kind instead of a mutable pickle per tag), and
CSV output."""
from __future__ import annotations

import os
import time
from typing import Optional

import numpy as np

from repro.core.perfmodel import PerfModel, fit_perf_model
from repro.profiler.dataset import (PerfDataset, simulate_dlt_dataset,
                                    simulate_primitive_dataset)
from repro.service.artifacts import ArtifactStore

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")
FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

_store_state: list = []          # lazily built: [ArtifactStore] or [None]


def store() -> Optional[ArtifactStore]:
    """The benchmark artifact store, created on first use (importing this
    module must not create directories). None if the root is unwritable —
    benchmarks then run cache-less rather than crash."""
    if not _store_state:
        try:
            _store_state.append(ArtifactStore(ART))
        except OSError:
            _store_state.append(None)
    return _store_state[0]

_ds_cache = {}


def dataset(platform: str) -> PerfDataset:
    if ("prim", platform) not in _ds_cache:
        _ds_cache[("prim", platform)] = simulate_primitive_dataset(
            platform, max_triplets=60 if FAST else None)
    return _ds_cache[("prim", platform)]


def dlt_dataset(platform: str) -> PerfDataset:
    if ("dlt", platform) not in _ds_cache:
        _ds_cache[("dlt", platform)] = simulate_dlt_dataset(platform)
    return _ds_cache[("dlt", platform)]


def trained_model(tag: str, kind: str, ds: PerfDataset, *,
                  max_iters: int = 8000, seed: int = 0,
                  base: Optional[PerfModel] = None,
                  cache: bool = True) -> PerfModel:
    iters = max_iters if not FAST else min(max_iters, 2000)

    def train() -> PerfModel:
        tr, va, te = ds.split()
        return fit_perf_model(kind, tr.feats, tr.times, va.feats, va.times,
                              columns=ds.columns, seed=seed, base=base,
                              max_iters=iters)

    st = store()
    if not cache or base is not None or st is None:
        return train()
    fields = {"artifact": "perfmodel", "tag": tag, "platform": ds.platform,
              "columns": list(ds.columns), "dataset": ds.fingerprint(),
              "model_kind": kind, "seed": seed, "max_iters": iters}
    try:
        model = st.get_model(fields)
    except Exception:
        model = None
    if model is not None:
        return model
    model = train()
    try:
        st.put_model(fields, model)
    except Exception:
        pass                 # caching failures never kill a benchmark run
    return model


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Contract from the scaffold: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.3f},{derived}")
