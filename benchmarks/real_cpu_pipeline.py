"""Real-hardware validation (DESIGN.md §2.1): profile actual JAX primitives
on this container's CPU, train a perf model on the measurements, PBQP-select
for AlexNet, execute the selected network and compare wall-clock against a
fixed-primitive baseline. Small scale — the simulators carry the full-size
study; this proves the pipeline on physical hardware."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, emit
from repro.core.perfmodel import fit_perf_model
from repro.core.selection import MeasuredProvider, ModelProvider, select
from repro.models import cnn_zoo
from repro.primitives.executor import execute, make_weights
from repro.profiler import host

PRIMS = ["im2col-copy-ab-ki", "im2col-scan-ab-ki", "kn2row", "direct-sum2d",
         "mec-col", "winograd-2x2-3x3", "winograd-4x4-3x3", "conv-1x1-gemm-ab-ki"]


def main() -> dict:
    # 1. profile a small config pool on THIS cpu
    pool = [(16, 8, 28, 1, 3), (32, 16, 28, 1, 3), (32, 16, 14, 1, 3),
            (64, 32, 14, 1, 3), (16, 8, 28, 2, 3), (32, 16, 28, 1, 1),
            (64, 32, 14, 1, 1), (16, 8, 28, 1, 5), (32, 16, 14, 1, 5),
            (64, 64, 7, 1, 3), (48, 24, 20, 1, 3), (24, 12, 24, 1, 3)]
    if FAST:
        pool = pool[:6]
    t0 = time.perf_counter()
    ds = host.profile_primitive_dataset(pool, primitives=PRIMS, repeats=5)
    t_profile = time.perf_counter() - t0
    dlt = host.profile_dlt_dataset([(8, 28), (16, 28), (32, 14), (64, 7)], repeats=5)

    # 2. train small models on the measurements
    n = ds.n
    m = fit_perf_model("nn2", ds.feats[:n - 2], ds.times[:n - 2],
                       ds.feats[n - 2:], ds.times[n - 2:],
                       columns=ds.columns, max_iters=1500, patience=150)
    md = fit_perf_model("lin", dlt.feats[:-1], dlt.times[:-1],
                        dlt.feats[-1:], dlt.times[-1:], columns=dlt.columns)
    mdrae_fit = m.mdrae(ds.feats, ds.times)

    # 3. select for a reduced AlexNet-like chain and execute for real
    from repro.models.cnn_zoo import CNNSpec, ConvLayer
    spec = CNNSpec("mini-alexnet", [
        ConvLayer("c1", 16, 8, 28, 1, 3), ConvLayer("c2", 32, 16, 26, 1, 3),
        ConvLayer("c3", 64, 32, 24, 1, 3), ConvLayer("c4", 64, 64, 22, 1, 1),
    ], [(0, 1), (1, 2), (2, 3)])
    provider = ModelProvider(m, md)
    provider.columns = PRIMS
    sel = select(spec, provider)
    weights = make_weights(spec)
    rep_sel = execute(spec, sel.assignment, weights, measure=True, repeats=5)
    base_assignment = {i: "direct-sum2d" for i in range(4)}
    rep_base = execute(spec, base_assignment, weights, measure=True, repeats=5)
    speedup = rep_base.total_seconds / max(rep_sel.total_seconds, 1e-12)

    emit("realcpu.profile_stage", t_profile * 1e6,
         f"configs={len(pool)} prims={len(PRIMS)}")
    emit("realcpu.model_fit_mdrae", mdrae_fit * 100, "")
    emit("realcpu.selected_exec", rep_sel.total_seconds * 1e6,
         f"baseline={rep_base.total_seconds*1e6:.0f}us speedup={speedup:.2f}x "
         f"assignment={[sel.assignment[i] for i in range(4)]}")
    return {"profile_s": t_profile, "mdrae": mdrae_fit, "speedup": speedup}


if __name__ == "__main__":
    main()
