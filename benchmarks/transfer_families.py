"""Paper Table 5: cross-family transfer — fine-tune the Intel model to AMD
with data from ONE primitive family, evaluate on every family. Rows are
normalised so the diagonal is 1."""
from __future__ import annotations

import numpy as np

from benchmarks.common import FAST, dataset, emit, trained_model
from repro.core.perfmodel import fit_perf_model
from repro.primitives.conv import FAMILIES, REGISTRY


def main() -> dict:
    intel = trained_model("nn2", "intel")
    ds = dataset("amd")
    tr, va, te = ds.split()
    col_fam = [REGISTRY[c].family for c in ds.columns]

    def fam_errs(model) -> dict:
        per = model.mdrae_per_column(te.feats, te.times)
        return {f: float(np.nanmedian([per[j] for j in range(len(per))
                                       if col_fam[j] == f]))
                for f in FAMILIES}

    mat = {}
    for train_fam in FAMILIES:
        # fine-tune with ONLY this family's labels (others masked out)
        times = tr.times.copy()
        for j, f in enumerate(col_fam):
            if f != train_fam:
                times[:, j] = np.nan
        m = fit_perf_model("nn2", tr.feats, times, va.feats, va.times,
                           columns=ds.columns, base=intel,
                           max_iters=2000 if not FAST else 800, patience=150)
        mat[train_fam] = fam_errs(m)

    results = {}
    for trf in FAMILIES:
        diag = mat[trf][trf]
        row = {evf: (mat[trf][evf] / diag if diag > 0 else float("nan"))
               for evf in FAMILIES}
        results[trf] = row
        emit(f"table5.{trf}", diag * 100,
             " ".join(f"{evf}={row[evf]:.1f}" for evf in FAMILIES))
    return results


if __name__ == "__main__":
    main()
