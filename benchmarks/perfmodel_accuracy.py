"""Paper Figs 4 & 5: MdRAE of Lin / NN1 / NN2 per primitive family.

Fig 4: all three model kinds on the intel dataset.
Fig 5: NN2 on amd / arm.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit, trained_model
from repro.primitives.conv import REGISTRY, FAMILIES


def _family_mdrae(model, te) -> dict:
    per_col = model.mdrae_per_column(te.feats, te.times)
    out = {}
    for fam in FAMILIES:
        vals = [per_col[j] for j, n in enumerate(te.columns)
                if REGISTRY[n].family == fam and np.isfinite(per_col[j])]
        out[fam] = float(np.median(vals)) if vals else float("nan")
    return out


def main() -> dict:
    results = {}
    ds = dataset("intel")
    tr, va, te = ds.split()
    for kind, iters in (("lin", 0), ("nn1", 2500), ("nn2", 8000)):
        m = trained_model(kind, "intel", max_iters=max(iters, 1))
        fam = _family_mdrae(m, te)
        overall = m.mdrae(te.feats, te.times)
        results[f"intel_{kind}"] = {"overall": overall, **fam}
        emit(f"fig4.intel.{kind}.mdrae", overall * 100,
             " ".join(f"{k}={v*100:.1f}%" for k, v in fam.items()))
    for plat in ("amd", "arm"):
        ds_p = dataset(plat)
        _, _, te_p = ds_p.split()
        m = trained_model("nn2", plat)
        fam = _family_mdrae(m, te_p)
        overall = m.mdrae(te_p.feats, te_p.times)
        results[f"{plat}_nn2"] = {"overall": overall, **fam}
        emit(f"fig5.{plat}.nn2.mdrae", overall * 100,
             " ".join(f"{k}={v*100:.1f}%" for k, v in fam.items()))
    return results


if __name__ == "__main__":
    main()
