"""DESIGN.md §2.2: the paper's technique on TPU kernel variants — NN2 cost
model over Pallas matmul block configs, PBQP-selected per matmul site for
every assigned architecture."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import base as cb
from repro.core.autotune import autotune_arch, train_cost_model


def main() -> dict:
    model = train_cost_model(max_iters=3000)
    results = {}
    for arch in cb.ASSIGNED_ARCHS:
        cfg = cb.get(arch)
        res = autotune_arch(cfg, model)
        gap = (res.predicted_s / res.oracle_s - 1.0) * 100 if res.oracle_s else 0.0
        results[arch] = {"speedup": res.speedup_vs_default,
                         "gap_to_oracle_pct": gap,
                         "assignment": res.assignment}
        emit(f"autotune.{arch}", res.predicted_s * 1e6,
             f"speedup_vs_default={res.speedup_vs_default:.2f}x "
             f"oracle_gap={gap:.1f}%")
    return results


if __name__ == "__main__":
    main()
