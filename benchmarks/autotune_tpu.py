"""DESIGN.md §2.2 + §9: the paper's technique on TPU kernel variants.

Two sections:

  * **LM matmul sites** (the original rows): NN2 cost model over Pallas
    matmul block configs, PBQP-selected per matmul site for every assigned
    architecture.
  * **CNN zoo through the platform path** (PR 6): the wide simulator base
    model is transferred onto ``PallasPlatform`` — whose 40 columns are
    (conv primitive, matmul tile config) pairs priced by the autotune cost
    surface — and the PBQP selects tile configs exactly like primitives.
    For each zoo net the model-selected assignment over ALL tile columns is
    scored against the same model restricted to the FIXED DEFAULT tile
    (the first ``VARIANTS`` entry), both under the ground-truth provider.

Writes ``BENCH_autotune.json``. ``--smoke`` (the CI gate) exits nonzero
unless the autotuned tile selection beats the fixed default tile config on
at least one zoo net — i.e. unless tile-config selection is actually worth
doing, the paper's premise applied to kernel autotuning.

Run:  PYTHONPATH=src:. python benchmarks/autotune_tpu.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict

from benchmarks.common import emit
from repro.configs import base as cb
from repro.core.autotune import autotune_arch, train_cost_model

OUT_PATH = os.environ.get("REPRO_BENCH_AUTOTUNE_JSON", "BENCH_autotune.json")

ZOO_NETS = ("edge_cnn", "squeezenet", "mobilenet")


def lm_rows(max_iters: int) -> Dict:
    model = train_cost_model(max_iters=max_iters)
    results = {}
    for arch in cb.ASSIGNED_ARCHS:
        cfg = cb.get(arch)
        res = autotune_arch(cfg, model)
        gap = (res.predicted_s / res.oracle_s - 1.0) * 100 if res.oracle_s else 0.0
        results[arch] = {"speedup": res.speedup_vs_default,
                         "gap_to_oracle_pct": gap,
                         "assignment": res.assignment}
        emit(f"autotune.{arch}", res.predicted_s * 1e6,
             f"speedup_vs_default={res.speedup_vs_default:.2f}x "
             f"oracle_gap={gap:.1f}%")
    return results


def cnn_rows(*, max_triplets: int, max_iters: int, nets=ZOO_NETS) -> Dict:
    """Transfer the simulator base onto the Pallas platform, then per net:
    PBQP over all (primitive, tile) columns vs the same model pinned to the
    default tile — both scored by the ground-truth tile cost provider."""
    from repro.core.selection import ModelProvider, build_pbqp, network_cost, select
    from repro.kernels.matmul.ops import VARIANTS
    from repro.models import cnn_zoo
    from repro.service import PallasPlatform, get_platform

    base = get_platform("intel", max_triplets=max_triplets).pretrain(
        "nn2", max_iters=max_iters)
    tpu = PallasPlatform(max_triplets=max_triplets)
    models = tpu.calibrate(base, budget=0.05, mode="factor")
    default_tile = next(iter(VARIANTS))
    default_cols = [c for c in tpu.columns if c.endswith(f"@{default_tile}")]
    truth = tpu.cost_provider()

    results: Dict = {"default_tile": default_tile,
                     "columns": len(tpu.columns), "nets": {}}
    for net in nets:
        spec = cnn_zoo.get(net)
        tuned = select(spec, models.provider())
        fixed = select(spec, models.provider(columns=default_cols))
        graph = build_pbqp(spec, truth)
        tuned_s = network_cost(spec, tuned.assignment, graph=graph)
        fixed_s = network_cost(spec, fixed.assignment, graph=graph)
        speedup = fixed_s / tuned_s if tuned_s else 0.0
        tiles = sorted({v.split("@")[1] for v in tuned.assignment.values()
                        if "@" in v})
        results["nets"][net] = {
            "autotuned_s": tuned_s, "default_tile_s": fixed_s,
            "speedup_vs_default_tile": speedup,
            "tiles_selected": tiles,
        }
        emit(f"autotune.cnn.{net}", tuned_s * 1e6,
             f"speedup_vs_default_tile={speedup:.3f}x "
             f"tiles={len(tiles)}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools / fewer iters; gate: autotuned tiles "
                         "must beat the default tile on >= 1 zoo net")
    args = ap.parse_args(argv)

    max_iters = 600 if args.smoke else 3000
    max_triplets = 30 if args.smoke else 60

    results = {"mode": "smoke" if args.smoke else "full",
               "lm": lm_rows(max_iters),
               "cnn": cnn_rows(max_triplets=max_triplets,
                               max_iters=max_iters)}
    with open(OUT_PATH, "w") as fh:
        json.dump(results, fh, indent=2)
    wins = [n for n, r in results["cnn"]["nets"].items()
            if r["speedup_vs_default_tile"] > 1.0]
    print(f"wrote {OUT_PATH} (autotuned tiles beat the default tile on "
          f"{len(wins)}/{len(results['cnn']['nets'])} nets)")

    if not wins:
        print("FAIL: autotuned tile selection did not beat the fixed "
              "default tile config on any zoo net", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
