"""§Roofline: the 40-cell baseline table, read from dry-run artifacts
(artifacts/dryrun/*.json — produced by ``python -m repro.launch.dryrun
--all``). Prints the per-cell three-term decomposition and flags cells over
the v5e HBM budget."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, emit

HBM = 16e9


def load(tag: str = "") -> list:
    suffix = f".{tag}.json" if tag else ".json"
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "dryrun", "*" + suffix))):
        base = os.path.basename(f)[:-len(suffix)]
        if not tag and base.count(".") > 2:
            continue            # skip tagged artifacts in the untagged view
        d = json.load(open(f))
        rows.append(d)
    return rows


def main() -> dict:
    results = {}
    for d in load():
        key = f"{d['arch']}.{d['shape']}.{'multi' if d['multi_pod'] else 'single'}"
        if d["status"] == "skipped":
            emit(f"roofline.{key}", 0.0, "SKIPPED (full attention)")
            continue
        if d["status"] != "ok":
            emit(f"roofline.{key}", 0.0, "ERROR")
            continue
        r = d["roofline"]
        mem = d["memory"]["peak_bytes_est"]
        over = " OVER-HBM" if mem > HBM else ""
        results[key] = r
        emit(f"roofline.{key}", r["step_time_s"] * 1e6,
             f"compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
             f"collective={r['collective_s']*1e3:.1f}ms dom={r['dominant']} "
             f"useful={r['useful_fraction']:.3f} mem={mem/1e9:.1f}GB{over}")
    return results


if __name__ == "__main__":
    main()
