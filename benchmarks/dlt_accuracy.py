"""Paper Fig 6: MdRAE of the data-layout-transformation cost models."""
from __future__ import annotations

from benchmarks.common import dlt_dataset, emit, trained_model


def main() -> dict:
    results = {}
    ds = dlt_dataset("intel")
    _, _, te = ds.split()
    for kind in ("lin", "nn1", "nn2"):
        m = trained_model(kind, "intel", role="dlt", max_iters=4000)
        overall = m.mdrae(te.feats, te.times)
        per = m.mdrae_per_column(te.feats, te.times)
        results[kind] = {"overall": overall,
                         **{c: float(p) for c, p in zip(te.columns, per)}}
        emit(f"fig6.dlt.{kind}.mdrae", overall * 100,
             " ".join(f"{c}={p*100:.1f}%" for c, p in zip(te.columns, per)))
    return results


if __name__ == "__main__":
    main()
