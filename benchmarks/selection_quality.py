"""Paper Fig 7: relative inference-time increase when optimising with
performance-model estimates instead of measured (simulated) times."""
from __future__ import annotations

from benchmarks.common import emit, trained_model
from repro.core.selection import (ModelProvider, SimulatedProvider, build_pbqp,
                                  network_cost, select)
from repro.models import cnn_zoo


def main() -> dict:
    results = {}
    for plat in ("intel", "amd", "arm"):
        prim_m = trained_model("nn2", plat)
        dlt_m = trained_model("nn2", plat, role="dlt")
        model = ModelProvider(prim_m, dlt_m)
        truth = SimulatedProvider(plat)
        for net in cnn_zoo.PAPER_SELECTION_NETS:
            spec = cnn_zoo.get(net)
            sel_model = select(spec, model)
            sel_truth = select(spec, truth)
            c_model = network_cost(spec, sel_model.assignment,
                                   graph=build_pbqp(spec, truth))
            c_truth = sel_truth.solver_cost
            inc = 100.0 * (c_model / c_truth - 1.0)
            results[f"{plat}.{net}"] = inc
            emit(f"fig7.{plat}.{net}.increase_pct", inc,
                 f"truth={c_truth*1e3:.3f}ms model={c_model*1e3:.3f}ms")
    return results


if __name__ == "__main__":
    main()
